"""Durable DatasetStore: the acceptance bar is the crash-recovery
round-trip — kill mid-append (torn WAL tail), reopen, and the store is at
the EXACT pre-crash version with a byte-identical capped snapshot, so the
refresher resumes serving with no refit downtime."""
import json

import numpy as np
import pytest

from repro.cluster import PersistentDatasetStore, WriteAheadLog
from repro.core.dataset import Sample

N_F = 8


def _sample(i: int, kernel: str = "k") -> Sample:
    return Sample(app="app", kernel=kernel, variant=f"v{i}",
                  features=np.full(N_F, float(i)),
                  targets={"d": {"time_us": float(i + 1)}})


def _fill(store, n: int, start: int = 0) -> None:
    for i in range(start, start + n):
        store.extend([_sample(i)])


# ---------------------------------------------------------------- round trip

def test_reopen_restores_exact_state(tmp_path):
    with PersistentDatasetStore(tmp_path, snapshot_every=3) as st:
        _fill(st, 5)
        pre_samples, pre_version = st.raw()
    with PersistentDatasetStore(tmp_path, snapshot_every=3) as st2:
        post_samples, post_version = st2.raw()
        assert post_version == pre_version == 5
        assert [s.to_json() for s in post_samples] == \
               [s.to_json() for s in pre_samples]


def test_kill_mid_append_replays_to_pre_crash_version(tmp_path):
    with PersistentDatasetStore(tmp_path, snapshot_every=4) as st:
        _fill(st, 6)                          # snapshot at v4, WAL holds v5-6
        pre = st.snapshot()
        pre_path = tmp_path / "pre.json"
        pre.dataset.save(pre_path)
    # the crash: a seventh append torn mid-write (no trailing newline)
    with open(tmp_path / "wal.jsonl", "ab") as f:
        f.write(b'{"v":7,"samples":[{"app":"app","ker')
    with PersistentDatasetStore(tmp_path, snapshot_every=4) as st2:
        assert st2.recovered_version == 6     # the torn batch was never acked
        assert st2.version == 6
        assert len(st2) == 6
        post = st2.snapshot()
        assert post.version == 6
        post_path = tmp_path / "post.json"
        post.dataset.save(post_path)
        assert post_path.read_bytes() == pre_path.read_bytes()
        # the store keeps working after recovery: next append is v7 again
        assert st2.extend([_sample(6)]) == 7


def test_recovery_without_any_snapshot(tmp_path):
    with PersistentDatasetStore(tmp_path, snapshot_every=100) as st:
        _fill(st, 3)                          # WAL only, no snapshot yet
    with PersistentDatasetStore(tmp_path, snapshot_every=100) as st2:
        assert st2.version == 3 and len(st2) == 3
        assert st2.replayed_records == 3


def test_unreadable_latest_snapshot_falls_back(tmp_path):
    with PersistentDatasetStore(tmp_path, snapshot_every=2,
                                keep_snapshots=4) as st:
        _fill(st, 4)                          # snapshots at v2 and v4
        snaps = sorted(tmp_path.glob("snapshot-*.json"))
        assert len(snaps) == 2
        _fill(st, 1, start=4)                 # v5 in the WAL
    snaps[-1].write_bytes(b"not json{{{")     # newest snapshot destroyed
    with PersistentDatasetStore(tmp_path, snapshot_every=2) as st2:
        # older snapshot (v2) + WAL... but the WAL was reset at v4, so only
        # v5 survives the log: recovery is best-effort v2 + v5 -> the WAL
        # record's version wins
        assert st2.version == 5
        assert len(st2) == 3                  # v1, v2 baked + v5 replayed


# ------------------------------------------------------------------- the WAL

def test_wal_truncates_torn_tail_before_appending(tmp_path):
    path = tmp_path / "wal.jsonl"
    wal = WriteAheadLog(path)
    wal.append(1, [{"a": 1}])
    wal.close()
    with open(path, "ab") as f:
        f.write(b'{"v":2,"samp')                  # torn
    wal2 = WriteAheadLog(path)
    assert wal2.recovered == [(1, [{"a": 1}])]
    wal2.append(2, [{"b": 2}])
    wal2.close()
    lines = path.read_bytes().splitlines()
    assert len(lines) == 2                        # torn bytes are gone
    assert json.loads(lines[1]) == {"v": 2, "samples": [{"b": 2}]}


def test_wal_corrupt_middle_record_raises(tmp_path):
    path = tmp_path / "wal.jsonl"
    path.write_bytes(b'{"v":1,"samples":[]}\nGARBAGE\n{"v":2,"samples":[]}\n')
    with pytest.raises(ValueError, match="corrupt WAL record"):
        WriteAheadLog(path)


def test_snapshot_resets_wal_and_prunes(tmp_path):
    with PersistentDatasetStore(tmp_path, snapshot_every=2,
                                keep_snapshots=2) as st:
        _fill(st, 9)
        assert (tmp_path / "wal.jsonl").stat().st_size > 0   # v9 pending
        st.checkpoint()
        assert (tmp_path / "wal.jsonl").stat().st_size == 0
        snaps = sorted(tmp_path.glob("snapshot-*.json"))
        assert len(snaps) == 2                # pruned to keep_snapshots
        assert snaps[-1].name == "snapshot-0000000009.json"


def test_closed_store_rejects_appends(tmp_path):
    st = PersistentDatasetStore(tmp_path)
    st.close()
    with pytest.raises(RuntimeError):
        st.extend([_sample(0)])


# -------------------------------------------------- refresher resume contract

def test_refresher_resumes_from_recovered_store_without_downtime(tmp_path):
    from repro.core.forest import ExtraTreesRegressor
    from repro.serve import EngineRefresher, ForestEngine

    rng = np.random.default_rng(2)

    def sample(i):
        x = rng.lognormal(1.0, 1.0, size=N_F)
        return Sample(app="app", kernel=f"k{i % 4}", variant=f"v{i}",
                      features=x,
                      targets={"d": {"time_us": float(x[0] * 3 + 1)}})

    def fit(ds):
        X, y, _ = ds.matrix("d", "time_us")
        return ExtraTreesRegressor(n_estimators=4, max_depth=4, seed=0).fit(
            X.astype(np.float32), np.log(y))

    with PersistentDatasetStore(tmp_path, snapshot_every=3) as st:
        st.extend([sample(i) for i in range(12)])
        pre_version = st.version
        est0 = fit(st.snapshot().dataset)
    # crash + restart: a fresh process opens the same directory
    with PersistentDatasetStore(tmp_path, snapshot_every=3) as st2:
        assert st2.version == pre_version
        eng = ForestEngine(est0, backend="flat-numpy")
        probe = np.full((1, N_F), 2.0, dtype=np.float32)
        before = eng.predict(probe)           # serving from the last good
        refresher = EngineRefresher(st2, eng, fit)   # generation already
        served = refresher.refresh_once()
        assert served == pre_version          # refit caught up in ONE cycle
        assert eng.generation == 1
        after = eng.predict(probe)
        # same data -> same refit forest -> identical answers: recovery
        # introduced no model discontinuity, only a generation bump
        np.testing.assert_allclose(before, after, rtol=1e-12)
        eng.close()
