import sys
from pathlib import Path

# NOTE: do NOT set XLA_FLAGS device-count here (the dry-run owns that);
# smoke tests and benches must see the single real CPU device.
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
