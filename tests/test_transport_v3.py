"""Protocol v3: binary zero-copy framing, per-connection negotiation with
v2 fallback, connection pipelining, and tenant auth at the hello.

The codec property tests mirror the v2 suite in test_transport.py: round
trips are bit-identical (NaN/±inf/subnormal float32 included — no decimal
detour), and ANY truncation, bit flip, or garbage stream raises the
documented TransportError/ProtocolError taxonomy, never hangs, never
decodes to a different payload."""
import json
import socket
import struct
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest
from _prop import given, settings, st

from repro.cluster import (PROTOCOL_V3, PROTOCOL_VERSION, AuthError,
                           ClusterFrontend, PredictionServer, ProtocolError,
                           RemoteReplica, ReplicaPool, TransportError)
from repro.cluster.remote import demo_estimator
from repro.cluster.transport import (MAX_FRAME_BYTES, V3_MAGIC, pack_array,
                                     recv_frame, recv_frame_v3, request_id,
                                     send_frame, send_frame_v3, unpack_array)
from repro.serve import ForestEngine

N_F = 6

_V3_HEADER = struct.Struct(">4sIII")


@pytest.fixture(scope="module")
def fitted():
    est = demo_estimator(seed=3, n_features=N_F, n_trees=12)
    rng = np.random.default_rng(7)
    X = rng.lognormal(1.0, 1.5, size=(64, N_F)).astype(np.float32)
    return est, X


def _serving(est, **fe_kw):
    pool = ReplicaPool(
        {"r0": ForestEngine(est, backend="flat-numpy", cache_size=0)},
        check_interval_s=60.0)
    fe_kw.setdefault("max_queue", 256)
    return ClusterFrontend(pool, auto_start=False, **fe_kw)


# ------------------------------------------------------------------- codec

SPECIALS = np.array([np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-45, -1e-45,
                     np.finfo(np.float32).tiny / 2, 1.5e38],
                    dtype=np.float32)


def _v3_frame(seed: int) -> tuple[dict, bytes, bytes]:
    """Random (meta, payload, raw wire bytes) with special floats mixed in."""
    rng = np.random.default_rng(seed)
    rows, cols = int(rng.integers(0, 9)), int(rng.integers(1, 7))
    arr = rng.normal(size=(rows, cols)).astype(np.float32)
    if arr.size:
        k = int(rng.integers(0, arr.size + 1))
        idx = rng.choice(arr.size, size=k, replace=False)
        arr.ravel()[idx] = rng.choice(SPECIALS, size=k)
    desc, payload = pack_array(arr)
    meta = {"v": PROTOCOL_V3, "id": request_id(), "op": "predict",
            "array": desc, "deadline_ms": float(rng.uniform(1, 1e4))}
    body = json.dumps(meta, separators=(",", ":")).encode()
    crc = zlib.crc32(payload, zlib.crc32(body))
    raw = _V3_HEADER.pack(V3_MAGIC, len(body), len(payload), crc) \
        + body + payload
    return meta, payload, raw


@given(st.integers(0, 2**31 - 1))
def test_prop_v3_roundtrip_is_identity(seed):
    meta, payload, _ = _v3_frame(seed)
    a, b = socket.socketpair()
    with a, b:
        send_frame_v3(a, meta, payload)
        send_frame_v3(a, meta, payload)          # self-delimiting
        a.close()
        for _ in range(2):
            got_meta, got_payload = recv_frame_v3(b)
            assert got_meta == meta
            assert got_payload == payload        # BIT-identical, NaNs and all
            back = unpack_array(got_meta["array"], got_payload)
            assert back.tobytes() == payload
        assert recv_frame_v3(b) is None


@settings(max_examples=25)
@given(st.integers(0, 2**31 - 1))
def test_prop_v3_truncated_stream_raises_never_hangs(seed):
    _, _, raw = _v3_frame(seed)
    rng = np.random.default_rng(seed ^ 0x5EED)
    cut = int(rng.integers(0, len(raw)))         # 0 = clean EOF
    a, b = socket.socketpair()
    with a, b:
        a.sendall(raw[:cut])
        a.close()
        if cut == 0:
            assert recv_frame_v3(b) is None
        else:
            with pytest.raises(TransportError) as ei:
                recv_frame_v3(b)
            assert ei.value.retryable


@settings(max_examples=40)
@given(st.integers(0, 2**31 - 1))
def test_prop_v3_bit_flip_always_detected(seed):
    """Any single flipped bit — magic, lengths, CRC, meta, or raw float
    payload — raises the documented taxonomy; it can never decode to a
    DIFFERENT array (CRC32 covers meta and payload together)."""
    meta, payload, raw = _v3_frame(seed)
    rng = np.random.default_rng(seed ^ 0xF11B)
    pos = int(rng.integers(0, len(raw)))
    bit = int(rng.integers(0, 8))
    fuzzed = bytearray(raw)
    fuzzed[pos] ^= 1 << bit
    a, b = socket.socketpair()
    with a, b:
        a.sendall(bytes(fuzzed))
        a.close()
        with pytest.raises((TransportError, ProtocolError)):
            recv_frame_v3(b)


@settings(max_examples=25)
@given(st.integers(0, 2**31 - 1))
def test_prop_v3_garbage_stream_raises_never_hangs(seed):
    """Random bytes are overwhelmingly a bad-magic ProtocolError; the four
    magic bytes matching by chance still dies on lengths/CRC. Either way
    the decoder raises instead of blocking on phantom bytes."""
    rng = np.random.default_rng(seed ^ 0x6A55)
    n = int(rng.integers(1, 64))
    raw = rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
    a, b = socket.socketpair()
    with a, b:
        a.sendall(raw)
        a.close()
        with pytest.raises((TransportError, ProtocolError)):
            recv_frame_v3(b)


def test_v3_oversized_lengths_rejected_before_body():
    a, b = socket.socketpair()
    with a, b:
        # lengths validated BEFORE the body is awaited: no further bytes
        # exist, yet this must not block
        a.sendall(_V3_HEADER.pack(V3_MAGIC, MAX_FRAME_BYTES, 2, 0))
        with pytest.raises(ProtocolError, match="exceeds"):
            recv_frame_v3(b)


def test_v3_wrong_magic_names_the_framing():
    a, b = socket.socketpair()
    with a, b:
        a.sendall(b"GET " + b"\x00" * 12)        # an HTTP peer, say
        with pytest.raises(ProtocolError, match="magic"):
            recv_frame_v3(b)


def test_unpack_array_rejects_hostile_descriptors():
    payload = np.zeros(4, dtype=np.float32).tobytes()
    for desc in (None, [], "x",                       # not an object
                 {"shape": [4], "dtype": "<i8"},      # dtype not allowed
                 {"shape": [4], "dtype": ">f4"},      # wrong endianness
                 {"shape": "4", "dtype": "<f4"},      # shape not a list
                 {"shape": [2, 2, 2, 2, 2], "dtype": "<f4"},   # rank > 4
                 {"shape": [-4], "dtype": "<f4"},     # negative dim
                 {"shape": [3], "dtype": "<f4"},      # length mismatch
                 {"shape": [4], "dtype": "<f8"}):     # itemsize mismatch
        with pytest.raises(ProtocolError):
            unpack_array(desc, payload)
    # the happy path really is zero-copy: a read-only view over the bytes
    out = unpack_array({"shape": [2, 2], "dtype": "<f4"}, payload)
    assert out.shape == (2, 2) and not out.flags.writeable


def test_pack_array_dtype_contract():
    desc32, p32 = pack_array(np.ones((2, 3), dtype=np.float32))
    assert desc32 == {"shape": [2, 3], "dtype": "<f4"} and len(p32) == 24
    desc64, p64 = pack_array(np.ones(5, dtype=np.float64))
    assert desc64 == {"shape": [5], "dtype": "<f8"} and len(p64) == 40


# ------------------------------------------------- negotiation + interop

def test_v3_negotiation_binary_predict_matches_in_process(fitted):
    est, X = fitted
    fe = _serving(est)
    local = ForestEngine(est, backend="flat-numpy", cache_size=0)
    with PredictionServer(fe, port=0) as server:
        with RemoteReplica(server.address, timeout_s=10.0) as replica:
            got = replica.predict(X, deadline_s=10.0)
            assert replica.negotiated_version == PROTOCOL_V3
            assert replica.n_features == N_F     # pinned at the hello
            np.testing.assert_allclose(got, local.predict(X),
                                       rtol=0, atol=1e-6)
            assert replica.stats.connects == 1


def test_v2_pinned_peer_works_against_v3_server(fitted):
    """Rolling upgrade, server first: a not-yet-upgraded client never sends
    a hello, speaks plain v2 JSON, and the v3 server serves it unchanged."""
    est, X = fitted
    fe = _serving(est)
    local = ForestEngine(est, backend="flat-numpy", cache_size=0)
    with PredictionServer(fe, port=0) as server:
        with RemoteReplica(server.address, timeout_s=10.0,
                           protocol=PROTOCOL_VERSION) as replica:
            got = replica.predict(X[:16], deadline_s=10.0)
            assert replica.negotiated_version == PROTOCOL_VERSION
            np.testing.assert_allclose(got, local.predict(X[:16]),
                                       rtol=0, atol=1e-6)


def test_mixed_v2_v3_peers_interleave_on_one_server(fitted):
    est, X = fitted
    fe = _serving(est)
    local = ForestEngine(est, backend="flat-numpy", cache_size=0)
    want = local.predict(X[:8])
    with PredictionServer(fe, port=0) as server:
        with RemoteReplica(server.address, timeout_s=10.0) as v3, \
                RemoteReplica(server.address, timeout_s=10.0,
                              protocol=PROTOCOL_VERSION) as v2:
            for _ in range(3):                   # interleaved dialects
                np.testing.assert_allclose(v3.predict(X[:8]), want,
                                           rtol=0, atol=1e-6)
                np.testing.assert_allclose(v2.predict(X[:8]), want,
                                           rtol=0, atol=1e-6)
            assert v3.negotiated_version == PROTOCOL_V3
            assert v2.negotiated_version == PROTOCOL_VERSION


def _legacy_server(est) -> tuple[socket.socket, threading.Thread]:
    """A pre-v3 server: v2 JSON only, and 'hello' is an unknown op that
    gets a BadRequest on a connection that STAYS OPEN — exactly the PR-4
    behavior the fallback path must interoperate with."""
    engine = ForestEngine(est, backend="flat-numpy", cache_size=0)
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)

    def serve():
        conn, _ = lst.accept()
        with conn:
            while True:
                try:
                    frame = recv_frame(conn)
                except (TransportError, ProtocolError):
                    return
                if frame is None:
                    return
                rid = frame.get("id")
                op = frame.get("op")
                if op == "info":
                    send_frame(conn, {"v": PROTOCOL_VERSION, "id": rid,
                                      "ok": True, "n_features": N_F,
                                      "server_version": PROTOCOL_VERSION})
                elif op == "predict":
                    y = engine.predict(np.asarray(frame["x"],
                                                  dtype=np.float32))
                    send_frame(conn, {"v": PROTOCOL_VERSION, "id": rid,
                                      "ok": True, "y": [float(v) for v in y]})
                else:                            # hello included
                    send_frame(conn, {"v": PROTOCOL_VERSION, "id": rid,
                                      "ok": False,
                                      "error": {"type": "BadRequest",
                                                "message":
                                                    f"unknown op {op!r}"}})

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    return lst, t


def test_v3_client_falls_back_to_v2_against_legacy_server(fitted):
    """Rolling upgrade, client first: the hello bounces off a legacy server
    as a BadRequest, the client downgrades to v2 JSON ON THE SAME SOCKET
    (no reconnect, no resend counted), and predictions flow."""
    est, X = fitted
    local = ForestEngine(est, backend="flat-numpy", cache_size=0)
    lst, thread = _legacy_server(est)
    try:
        port = lst.getsockname()[1]
        with RemoteReplica("127.0.0.1", port, timeout_s=10.0) as replica:
            got = replica.predict(X[:4])
            assert replica.negotiated_version == PROTOCOL_VERSION
            assert replica.stats.connects == 1   # same socket throughout
            assert replica.stats.resends == 0
            assert replica.stats.remote_errors == 0   # fallback isn't an error
            np.testing.assert_allclose(got, local.predict(X[:4]),
                                       rtol=0, atol=1e-6)
    finally:
        lst.close()
        thread.join(timeout=5)


# ---------------------------------------------------------- pipelining

class GatedEngine:
    def __init__(self):
        self.n_features = N_F
        self.gate = threading.Event()
        self.calls = 0

    def predict(self, X):
        self.calls += 1
        if not self.gate.wait(timeout=30):
            raise RuntimeError("gate never released")
        return np.atleast_2d(np.asarray(X))[:, 0].astype(np.float64)

    def swap_estimator(self, est):
        return 0

    def close(self):
        self.gate.set()


def test_pipelining_multiplexes_requests_on_one_socket():
    """Concurrent predicts share ONE connection with many request ids in
    flight at once; when the engine releases, every waiter gets ITS OWN
    answer back (out-of-order reply matching by id, not FIFO)."""
    engine = GatedEngine()
    pool = ReplicaPool({"r0": engine}, check_interval_s=60.0)
    fe = ClusterFrontend(pool, max_queue=64, dispatch_batch=4,
                         auto_start=False)
    with PredictionServer(fe, port=0) as server:
        with RemoteReplica(server.address, timeout_s=15.0) as replica:
            rows = [np.full(N_F, float(i + 1), dtype=np.float32)
                    for i in range(8)]
            with ThreadPoolExecutor(max_workers=8) as ex:
                futs = [ex.submit(replica.predict, r[None, :])
                        for r in rows]
                deadline = time.monotonic() + 10
                while (replica.stats.max_in_flight < 8
                       and time.monotonic() < deadline):
                    time.sleep(0.005)            # all 8 pending on 1 socket
                assert replica.stats.max_in_flight == 8
                engine.gate.set()
                got = [f.result(timeout=15) for f in futs]
            for i, y in enumerate(got):
                assert y[0] == pytest.approx(i + 1.0)
            assert replica.stats.connects == 1


def test_pipelined_deadlines_are_per_request():
    """One hopeless deadline on the shared socket fails ONLY its own
    request — the sibling with budget is answered on the same connection."""
    engine = GatedEngine()
    pool = ReplicaPool({"r0": engine}, check_interval_s=60.0)
    fe = ClusterFrontend(pool, max_queue=64, dispatch_batch=1,
                         auto_start=False)
    from repro.cluster import DeadlineExceeded
    with PredictionServer(fe, port=0) as server:
        with RemoteReplica(server.address, timeout_s=15.0) as replica:
            x = np.full(N_F, 2.0, dtype=np.float32)
            with ThreadPoolExecutor(max_workers=3) as ex:
                blocker = ex.submit(replica.predict,
                                    np.full(N_F, 1.0, dtype=np.float32))
                deadline = time.monotonic() + 10
                while engine.calls < 1 and time.monotonic() < deadline:
                    time.sleep(0.005)            # blocker owns the engine
                assert engine.calls == 1
                doomed = ex.submit(replica.predict, x[None, :],
                                   deadline_s=0.05)
                ok = ex.submit(replica.predict, x[None, :], deadline_s=30.0)
                time.sleep(0.2)                  # doomed expires IN QUEUE
                engine.gate.set()
                with pytest.raises(DeadlineExceeded):
                    doomed.result(timeout=15)
                assert ok.result(timeout=15)[0] == pytest.approx(2.0)
                assert blocker.result(timeout=15)[0] == pytest.approx(1.0)
            assert replica.stats.connects == 1


# ---------------------------------------------------------------- auth

def test_hello_auth_gates_every_op(fitted):
    est, X = fitted
    fe = _serving(est)
    with PredictionServer(fe, port=0,
                          tenants={"acme": "s3cr3t"}) as server:
        # no credentials at all: the hello itself is refused
        with pytest.raises(AuthError, match="tenant"):
            RemoteReplica(server.address, timeout_s=10.0).predict(X[:2])
        # wrong token: refused, and the error names the tenant
        with pytest.raises(AuthError, match="acme"):
            RemoteReplica(server.address, timeout_s=10.0,
                          tenant="acme", token="wrong").predict(X[:2])
        # right token: binary framing + predictions flow
        with RemoteReplica(server.address, timeout_s=10.0,
                           tenant="acme", token="s3cr3t") as replica:
            assert replica.predict(X[:4]).shape == (4,)
            assert replica.negotiated_version == PROTOCOL_V3
        # AuthError is NOT retryable backpressure: no resend burned
        bad = RemoteReplica(server.address, timeout_s=10.0,
                            tenant="nobody", token="s3cr3t")
        with pytest.raises(AuthError):
            bad.predict(X[:2])
        assert bad.stats.resends == 0


def test_v2_pinned_peer_authenticates_on_json(fitted):
    """Auth works for not-yet-upgraded peers too: a hello with max_v=2
    authenticates, then stays on JSON framing."""
    est, X = fitted
    fe = _serving(est)
    local = ForestEngine(est, backend="flat-numpy", cache_size=0)
    with PredictionServer(fe, port=0,
                          tenants={"acme": "s3cr3t"}) as server:
        with RemoteReplica(server.address, timeout_s=10.0,
                           protocol=PROTOCOL_VERSION, tenant="acme",
                           token="s3cr3t") as replica:
            got = replica.predict(X[:4])
            assert replica.negotiated_version == PROTOCOL_VERSION
            np.testing.assert_allclose(got, local.predict(X[:4]),
                                       rtol=0, atol=1e-6)


def test_unauthenticated_raw_op_is_refused(fitted):
    """A peer that skips the hello entirely (hand-rolled frames) cannot
    reach any op on a tenants-configured server."""
    est, _ = fitted
    fe = _serving(est)
    with PredictionServer(fe, port=0,
                          tenants={"acme": "s3cr3t"}) as server:
        with socket.create_connection(server.address, timeout=5.0) as sock:
            send_frame(sock, {"v": PROTOCOL_VERSION, "id": request_id(),
                              "op": "info"})
            resp = recv_frame(sock)
            assert resp["ok"] is False
            assert resp["error"]["type"] == "Unauthorized"
            # and the server hung up on the unauthenticated peer
            assert recv_frame(sock) is None
