"""ClusterFrontend batch admission + per-tenant quotas.

``submit_batch`` admits a whole (B, F) batch as ONE queue entry (atomic:
all rows or none), ``max_queue`` bounds ROWS, and ``tenant_quotas`` carves
that bound into per-tenant slices so one hog cannot starve the rest — the
fairness-under-saturation test replays a PR-6 tenant-mix trace at 1.2x
measured capacity and checks the overload lands on the tenant causing it."""
import threading
import time

import numpy as np
import pytest

from repro.cluster import (ClusterFrontend, FrontendRejected, ReplicaPool)
from repro.workloads.trace import SERVED, SHED, TraceReplayer, gen_tenant_mix

N_F = 6


class InstantEngine:
    def __init__(self):
        self.n_features = N_F
        self.calls = 0

    def predict(self, X):
        self.calls += 1
        return np.atleast_2d(np.asarray(X))[:, 0].astype(np.float64)

    def swap_estimator(self, est):
        return 0

    def close(self):
        pass


class SleepyEngine(InstantEngine):
    """Fixed service time per dispatch -> known capacity for the
    saturation test: ``dispatch_batch / sleep_s`` rows per second."""

    def __init__(self, sleep_s: float):
        super().__init__()
        self.sleep_s = sleep_s

    def predict(self, X):
        time.sleep(self.sleep_s)
        return super().predict(X)


class GatedEngine(InstantEngine):
    def __init__(self):
        super().__init__()
        self.gate = threading.Event()

    def predict(self, X):
        if not self.gate.wait(timeout=30):
            raise RuntimeError("gate never released")
        return super().predict(X)

    def close(self):
        self.gate.set()


def _frontend(engine, **kw):
    pool = ReplicaPool({"r0": engine}, check_interval_s=60.0)
    kw.setdefault("max_queue", 64)
    return ClusterFrontend(pool, auto_start=False, **kw)


def _rows(vals):
    return np.stack([np.full(N_F, float(v), dtype=np.float32)
                     for v in vals])


# ------------------------------------------------------------ submit_batch

def test_submit_batch_matches_per_row_submits():
    fe = _frontend(InstantEngine(), dispatch_batch=4)
    try:
        X = _rows([1, 2, 3, 4, 5])
        fut = fe.submit_batch(X, deadline_s=10.0)
        singles = [fe.submit(X[i], deadline_s=10.0) for i in range(5)]
        fe.start()
        got = fut.result(timeout=10)
        assert got.shape == (5,) and got.dtype == np.float64
        np.testing.assert_allclose(got, [1, 2, 3, 4, 5])
        np.testing.assert_allclose([s.result(timeout=10) for s in singles],
                                   [1, 2, 3, 4, 5])
        assert fe.stats.served == 10           # row-counted either way
    finally:
        fe.close()


def test_submit_batch_empty_and_validation():
    fe = _frontend(InstantEngine())
    try:
        out = fe.submit_batch(np.empty((0, N_F), dtype=np.float32))
        assert out.result(timeout=1).shape == (0,)
        with pytest.raises(ValueError, match="batch"):
            fe.submit_batch(np.zeros(N_F, dtype=np.float32))
        with pytest.raises(ValueError):
            fe.submit_batch(np.zeros((2, N_F + 1), dtype=np.float32))
    finally:
        fe.close()


def test_submit_batch_admission_is_atomic():
    """A batch that does not fit is rejected WHOLE: nothing queued, no
    sibling cancellations, the engine never sees a partial batch."""
    engine = GatedEngine()
    fe = _frontend(engine, max_queue=3, dispatch_batch=1)
    try:
        with pytest.raises(FrontendRejected) as ei:
            fe.submit_batch(_rows([1, 2, 3, 4, 5, 6]))
        assert ei.value.retry_after_s >= 0.0
        assert fe.queued_rows() == 0           # all-or-nothing
        assert fe.stats.rejected == 6          # rows, not batches
        assert fe.stats.cancelled == 0
        fut = fe.submit_batch(_rows([7, 8]))   # a fitting batch still lands
        engine.gate.set()
        fe.start()
        np.testing.assert_allclose(fut.result(timeout=10), [7, 8])
    finally:
        fe.close()


def test_batch_rows_count_against_max_queue():
    """max_queue bounds ROWS across entries: a 4-row batch plus singles
    saturates a queue of 6 exactly like six singles would."""
    engine = GatedEngine()
    fe = _frontend(engine, max_queue=6, dispatch_batch=1)
    try:
        fe.submit_batch(_rows([1, 2, 3, 4]))
        fe.submit(_rows([5])[0])
        fe.submit(_rows([6])[0])
        assert fe.queued_rows() == 6
        with pytest.raises(FrontendRejected):
            fe.submit(_rows([7])[0])
    finally:
        fe.close()


# ----------------------------------------------------------------- quotas

def test_tenant_quota_slices_the_queue():
    engine = GatedEngine()
    fe = _frontend(engine, max_queue=64, dispatch_batch=1,
                   tenant_quotas={"a": 3, "*": 2})
    try:
        for _ in range(3):
            fe.submit(_rows([1])[0], tenant="a")
        with pytest.raises(FrontendRejected):   # a is at ITS cap, queue isn't
            fe.submit(_rows([1])[0], tenant="a")
        assert fe.stats.quota_rejected == 1
        # an unnamed tenant falls to the "*" default cap
        fe.submit(_rows([2])[0], tenant="b")
        fe.submit(_rows([2])[0], tenant="b")
        with pytest.raises(FrontendRejected):
            fe.submit(_rows([2])[0], tenant="b")
        assert fe.stats.quota_rejected == 2
        assert fe.queued_rows("a") == 3 and fe.queued_rows("b") == 2
        assert fe.stats.by_tenant["a"]["rejected"] == 1
    finally:
        fe.close()


def test_quota_rows_release_on_dispatch():
    engine = InstantEngine()
    fe = _frontend(engine, max_queue=64, tenant_quotas={"a": 2})
    try:
        fe.start()
        for _ in range(5):                      # 5 rows through a quota of 2
            fe.submit(_rows([3])[0], tenant="a").result(timeout=10)
        assert fe.stats.by_tenant["a"]["served"] == 5
        assert fe.queued_rows("a") == 0
    finally:
        fe.close()


def test_quota_batch_rejection_is_atomic_too():
    engine = GatedEngine()
    fe = _frontend(engine, max_queue=64, dispatch_batch=1,
                   tenant_quotas={"a": 4})
    try:
        fe.submit_batch(_rows([1, 2, 3]), tenant="a")
        with pytest.raises(FrontendRejected):   # 3 + 2 > 4
            fe.submit_batch(_rows([4, 5]), tenant="a")
        assert fe.queued_rows("a") == 3
        assert fe.stats.quota_rejected == 2     # row-counted, like served
        fe.submit(_rows([6])[0], tenant="a")    # 1 more still fits
        assert fe.queued_rows("a") == 4
    finally:
        fe.close()


# --------------------------------------------- fairness under saturation

def test_three_tenant_fairness_at_1p2x_capacity():
    """The acceptance bar: a hog tenant offering ~3x its fair share at
    1.2x total capacity bears the overload; the two polite tenants ride
    their quota slices mostly unshed. Reuses the PR-6 tenant-mix trace
    generator and open-loop replayer (which forwards each event's tenant
    into the quota accounting)."""
    sleep_s, batch = 0.006, 4                  # capacity ~ 666 rows/s
    engine = SleepyEngine(sleep_s)
    fe = _frontend(engine, max_queue=48, dispatch_batch=batch,
                   tenant_quotas={"hog": 16, "*": 16})
    from repro.workloads.trace import synthetic_catalog
    ids, X = synthetic_catalog(8, N_F, seed=5)
    trace = gen_tenant_mix(
        ids, X, duration_s=1.5, seed=42,
        tenants={"hog": {"rate": 640.0, "deadline_band": None},
                 "polite-1": {"rate": 80.0, "deadline_band": None},
                 "polite-2": {"rate": 80.0, "deadline_band": None}})
    # ~1200 arrivals over 1.5 s = 1.2x the ~666 rows/s the engine serves
    assert len(trace.events) > 900
    try:
        fe.start()
        rep = TraceReplayer(fe, pacing="open", speed=1.0,
                            max_retries=0, timeout_s=60.0).replay(trace)
    finally:
        fe.close()
    t = rep.per_tenant
    hog, p1, p2 = t["hog"], t["polite-1"], t["polite-2"]
    # every tenant makes progress — no starvation in either direction
    for s in (hog, p1, p2):
        assert s.served > 0
    # the quota actually bit, and it bit the tenant causing the overload
    assert fe.stats.quota_rejected > 0
    assert hog.shed > 0
    # bounded unfairness: polite tenants' shed fraction stays small and
    # strictly below the hog's (loose bounds — CI machines vary)
    assert hog.shed_fraction() > max(p1.shed_fraction(), p2.shed_fraction())
    assert p1.shed_fraction() < 0.25 and p2.shed_fraction() < 0.25
    assert p1.served / p1.submitted >= 0.6
    assert p2.served / p2.submitted >= 0.6
    # and the frontend's own books agree on who was turned away
    assert fe.stats.by_tenant["hog"]["rejected"] > 0
    assert rep.count(SERVED) + rep.count(SHED) <= len(trace.events)
