"""ShardedForestEngine: tree-axis partitioning must be a pure refactor of
the forest mean — predictions match the tree-walk oracle to <=1e-5 rel on
forced multi-shard configurations (the acceptance bar), uneven tree counts
included, through both the dense-jax and Pallas per-shard paths, with the
engine features (cache, async, hot-swap, scheduler frontend) intact. The
shard_map mesh placement is exercised in a forced-device-count subprocess
(XLA device count is fixed at import time in-process)."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.forest import ExtraTreesRegressor
from repro.core.scheduler import DevicePredictor, predict_matrix
from repro.serve import (PredictorBackend, ServingEngine,
                         ShardedForestEngine, ShardedForestPredictor)


def _rel(pred, oracle):
    return np.max(np.abs(pred - oracle) / np.maximum(np.abs(oracle), 1e-9))


@pytest.fixture(scope="module")
def fitted():
    rng = np.random.default_rng(3)
    X = rng.lognormal(1.0, 1.5, size=(140, 10)).astype(np.float32)
    y = np.log(2 * X[:, 0] + 0.5 * X[:, 3] + 3.0) + 0.05 * rng.normal(size=140)
    # depth < dense_depth so the dense embedding (hence sharding) is exact
    est = ExtraTreesRegressor(n_estimators=10, max_depth=6, seed=0).fit(X, y)
    return est, X


# ---------------------------------------------------------------- correctness

@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_sharded_matches_tree_walk_oracle(fitted, n_shards):
    est, X = fitted
    oracle = est.predict(X)
    with ShardedForestEngine(est, n_shards=n_shards, cache_size=0) as eng:
        assert eng.placement == "loop"          # 1 visible device here
        assert len(eng.shard_sizes) == n_shards
        assert sum(eng.shard_sizes) == len(est.trees_)
        assert _rel(eng.predict(X), oracle) <= 1e-5


def test_sharded_pallas_path_matches_oracle(fitted):
    est, X = fitted
    oracle = est.predict(X)
    with ShardedForestEngine(est, n_shards=2, use_pallas=True,
                             cache_size=0) as eng:
        assert "pallas" in eng.backend
        assert _rel(eng.predict(X[:32]), oracle[:32]) <= 1e-5


def test_uneven_tree_split(fitted):
    est, X = fitted
    oracle = est.predict(X)
    with ShardedForestEngine(est, n_shards=3, cache_size=0) as eng:
        # 10 trees over 3 shards: balanced, none empty
        assert sorted(eng.shard_sizes) == [3, 3, 4]
        assert _rel(eng.predict(X), oracle) <= 1e-5


def test_shards_clamped_to_tree_count(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=64, cache_size=0) as eng:
        assert len(eng.shard_sizes) == len(est.trees_)
        assert _rel(eng.predict(X[:16]), est.predict(X[:16])) <= 1e-5


def test_predictor_rejects_bad_shards(fitted):
    est, _ = fitted
    with pytest.raises(ValueError):
        ShardedForestPredictor(est, n_shards=0)


def test_rejects_explicit_backend_config(fitted):
    est, _ = fitted
    from repro.serve import EngineConfig
    with pytest.raises(ValueError):
        ShardedForestEngine(est, EngineConfig(backend="flat-numpy"))
    with pytest.raises(ValueError):
        ShardedForestEngine(est, backend="tree-walk")


# ------------------------------------------------------------- engine surface

def test_sharded_is_a_serving_engine(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=2) as eng:
        assert isinstance(eng, ServingEngine)
        assert isinstance(ShardedForestPredictor(est, n_shards=2),
                          PredictorBackend)
        # async micro-batching + cache inherited from ForestEngine
        futs = [eng.predict_async(X[i]) for i in range(8)]
        got = np.array([f.result(timeout=10) for f in futs])
        np.testing.assert_allclose(got, est.predict(X[:8]), rtol=1e-5)
        eng.predict(X[:8])
        assert eng.stats.cache_hits >= 8


def test_sharded_in_scheduler_frontend(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=2, cache_size=0) as eng:
        T, _ = predict_matrix(X[:20], [DevicePredictor("dev", eng)])
        np.testing.assert_allclose(T[:, 0], np.exp(est.predict(X[:20])),
                                   rtol=1e-5)


def test_sharded_hot_swap(fitted):
    est, X = fitted
    rng = np.random.default_rng(0)
    y2 = np.log(X[:, 1] + 1.0) + rng.normal(size=X.shape[0]) * 0.01
    est2 = ExtraTreesRegressor(n_estimators=7, max_depth=5, seed=1).fit(X, y2)
    with ShardedForestEngine(est, n_shards=2) as eng:
        p1 = eng.predict(X[:10])
        gen = eng.swap_estimator(est2)
        assert gen == 1 and eng.stats.swaps == 1
        # swap re-partitions the NEW forest (7 trees over 2 shards)
        assert sum(eng.shard_sizes) == 7
        p2 = eng.predict(X[:10])
        np.testing.assert_allclose(p2, est2.predict(X[:10]), rtol=1e-5)
        assert not np.allclose(p1, p2)


# -------------------------------------------------------------- shard failure

def test_drop_shard_renormalizes_over_survivors(fitted):
    """The acceptance bar: a forced shard failure keeps predictions flowing,
    and the renormalized mean matches the tree-walk oracle restricted to the
    surviving trees to <=1e-5 rel."""
    est, X = fitted
    with ShardedForestEngine(est, n_shards=3, cache_size=32) as eng:
        full = eng.predict(X)
        lost = eng.drop_shard(1)
        assert lost == 3                          # 10 trees -> [4, 3, 3]
        assert eng.shard_sizes == [4, 3]          # survivors only
        assert eng.dead_shards == frozenset({1})
        assert eng.live_trees == len(est.trees_) - lost
        assert eng.backend.endswith("-deg1")
        pred = eng.predict(X)                     # still flowing
        survivors = eng.live_tree_indices()
        oracle = np.mean([est.trees_[i].predict(X) for i in survivors],
                         axis=0)
        assert _rel(pred, oracle) <= 1e-5
        assert not np.allclose(pred, full)        # degradation is real...
        assert eng.stats.shard_drops == 1         # ...and counted
        assert eng.stats.trees_lost == lost
        assert eng.stats.generation == 1          # stale cache entries gone


def test_drop_second_shard_compounds(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=4, cache_size=0) as eng:
        eng.drop_shard(0)
        eng.drop_shard(2)
        survivors = eng.live_tree_indices()
        assert len(survivors) == eng.live_trees
        oracle = np.mean([est.trees_[i].predict(X) for i in survivors],
                         axis=0)
        assert _rel(eng.predict(X), oracle) <= 1e-5
        assert eng.stats.shard_drops == 2
        assert eng.stats.trees_lost == len(est.trees_) - eng.live_trees


def test_drop_shard_validation(fitted):
    est, _ = fitted
    with ShardedForestEngine(est, n_shards=2, cache_size=0) as eng:
        with pytest.raises(ValueError):
            eng.drop_shard(5)                     # out of range
        eng.drop_shard(0)
        with pytest.raises(ValueError):
            eng.drop_shard(0)                     # already dead
        with pytest.raises(RuntimeError):
            eng.drop_shard(1)                     # last survivor


def test_swap_restores_full_forest_after_drop(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=3) as eng:
        eng.drop_shard(2)
        assert eng.stats.trees_lost > 0
        eng.swap_estimator(est)                   # the refresher's path
        assert eng.dead_shards == frozenset()
        assert eng.live_trees == len(est.trees_)
        assert eng.stats.trees_lost == 0          # degradation cleared
        assert eng.stats.shard_drops == 1         # history preserved
        assert _rel(eng.predict(X), est.predict(X)) <= 1e-5


def test_drop_shard_during_async_traffic(fitted):
    """Requests in flight across the drop all resolve; answers come
    uniformly from either the full or the degraded forest, never a mix."""
    est, X = fitted
    full_oracle = est.predict(X)
    with ShardedForestEngine(est, n_shards=2, max_batch=4,
                             max_delay_ms=0.5) as eng:
        futs = [eng.predict_async(X[i]) for i in range(24)]
        eng.drop_shard(0)
        futs += [eng.predict_async(X[i]) for i in range(24, 48)]
        got = np.array([f.result(timeout=30) for f in futs])
        survivors = eng.live_tree_indices()
        deg_oracle = np.mean([est.trees_[i].predict(X) for i in survivors],
                             axis=0)
        for i, v in enumerate(got):
            ok_full = abs(v - full_oracle[i]) <= 1e-5 * abs(full_oracle[i])
            ok_deg = abs(v - deg_oracle[i]) <= 1e-5 * abs(deg_oracle[i])
            assert ok_full or ok_deg


# ------------------------------------------------------------- mesh placement

def test_mesh_placement_subprocess(fitted):
    """shard_map over a real 2-device tree mesh (forced host devices) must
    match the oracle; in-process we can't change the device count."""
    code = """
import numpy as np
from repro.core.forest import ExtraTreesRegressor
from repro.serve import ShardedForestEngine

rng = np.random.default_rng(3)
X = rng.lognormal(1.0, 1.5, size=(32, 10)).astype(np.float32)
y = np.log(2 * X[:, 0] + 0.5 * X[:, 3] + 3.0)
est = ExtraTreesRegressor(n_estimators=6, max_depth=5, seed=0).fit(X, y)
with ShardedForestEngine(est, n_shards=2, cache_size=0) as eng:
    assert eng.placement == "mesh", eng.placement
    pred = eng.predict(X)
    # a shard dying out of a MESH placement degrades to the loop placement
    eng.drop_shard(0)
    assert eng.placement == "loop", eng.placement
    deg = eng.predict(X)
    live = eng.live_tree_indices()
oracle = est.predict(X)
rel = np.max(np.abs(pred - oracle) / np.maximum(np.abs(oracle), 1e-9))
assert rel <= 1e-5, rel
deg_oracle = np.mean([est.trees_[i].predict(X) for i in live], axis=0)
rel_deg = np.max(np.abs(deg - deg_oracle) / np.maximum(np.abs(deg_oracle), 1e-9))
assert rel_deg <= 1e-5, rel_deg
print("MESH_OK", rel, rel_deg)
"""
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300,
        env={**os.environ, "PYTHONPATH": src,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert proc.returncode == 0, proc.stderr
    assert "MESH_OK" in proc.stdout
