"""ShardedForestEngine: tree-axis partitioning must be a pure refactor of
the forest mean — predictions match the tree-walk oracle to <=1e-5 rel on
forced multi-shard configurations (the acceptance bar), uneven tree counts
included, through both the dense-jax and Pallas per-shard paths, with the
engine features (cache, async, hot-swap, scheduler frontend) intact. The
shard_map mesh placement is exercised in a forced-device-count subprocess
(XLA device count is fixed at import time in-process)."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.forest import ExtraTreesRegressor
from repro.core.scheduler import DevicePredictor, predict_matrix
from repro.serve import (PredictorBackend, ServingEngine,
                         ShardedForestEngine, ShardedForestPredictor)


def _rel(pred, oracle):
    return np.max(np.abs(pred - oracle) / np.maximum(np.abs(oracle), 1e-9))


@pytest.fixture(scope="module")
def fitted():
    rng = np.random.default_rng(3)
    X = rng.lognormal(1.0, 1.5, size=(140, 10)).astype(np.float32)
    y = np.log(2 * X[:, 0] + 0.5 * X[:, 3] + 3.0) + 0.05 * rng.normal(size=140)
    # depth < dense_depth so the dense embedding (hence sharding) is exact
    est = ExtraTreesRegressor(n_estimators=10, max_depth=6, seed=0).fit(X, y)
    return est, X


# ---------------------------------------------------------------- correctness

@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_sharded_matches_tree_walk_oracle(fitted, n_shards):
    est, X = fitted
    oracle = est.predict(X)
    with ShardedForestEngine(est, n_shards=n_shards, cache_size=0) as eng:
        assert eng.placement == "loop"          # 1 visible device here
        assert len(eng.shard_sizes) == n_shards
        assert sum(eng.shard_sizes) == len(est.trees_)
        assert _rel(eng.predict(X), oracle) <= 1e-5


def test_sharded_pallas_path_matches_oracle(fitted):
    est, X = fitted
    oracle = est.predict(X)
    with ShardedForestEngine(est, n_shards=2, use_pallas=True,
                             cache_size=0) as eng:
        assert "pallas" in eng.backend
        assert _rel(eng.predict(X[:32]), oracle[:32]) <= 1e-5


def test_uneven_tree_split(fitted):
    est, X = fitted
    oracle = est.predict(X)
    with ShardedForestEngine(est, n_shards=3, cache_size=0) as eng:
        # 10 trees over 3 shards: balanced, none empty
        assert sorted(eng.shard_sizes) == [3, 3, 4]
        assert _rel(eng.predict(X), oracle) <= 1e-5


def test_shards_clamped_to_tree_count(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=64, cache_size=0) as eng:
        assert len(eng.shard_sizes) == len(est.trees_)
        assert _rel(eng.predict(X[:16]), est.predict(X[:16])) <= 1e-5


def test_predictor_rejects_bad_shards(fitted):
    est, _ = fitted
    with pytest.raises(ValueError):
        ShardedForestPredictor(est, n_shards=0)


def test_rejects_explicit_backend_config(fitted):
    est, _ = fitted
    from repro.serve import EngineConfig
    with pytest.raises(ValueError):
        ShardedForestEngine(est, EngineConfig(backend="flat-numpy"))
    with pytest.raises(ValueError):
        ShardedForestEngine(est, backend="tree-walk")


# ------------------------------------------------------------- engine surface

def test_sharded_is_a_serving_engine(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=2) as eng:
        assert isinstance(eng, ServingEngine)
        assert isinstance(ShardedForestPredictor(est, n_shards=2),
                          PredictorBackend)
        # async micro-batching + cache inherited from ForestEngine
        futs = [eng.predict_async(X[i]) for i in range(8)]
        got = np.array([f.result(timeout=10) for f in futs])
        np.testing.assert_allclose(got, est.predict(X[:8]), rtol=1e-5)
        eng.predict(X[:8])
        assert eng.stats.cache_hits >= 8


def test_sharded_in_scheduler_frontend(fitted):
    est, X = fitted
    with ShardedForestEngine(est, n_shards=2, cache_size=0) as eng:
        T, _ = predict_matrix(X[:20], [DevicePredictor("dev", eng)])
        np.testing.assert_allclose(T[:, 0], np.exp(est.predict(X[:20])),
                                   rtol=1e-5)


def test_sharded_hot_swap(fitted):
    est, X = fitted
    rng = np.random.default_rng(0)
    y2 = np.log(X[:, 1] + 1.0) + rng.normal(size=X.shape[0]) * 0.01
    est2 = ExtraTreesRegressor(n_estimators=7, max_depth=5, seed=1).fit(X, y2)
    with ShardedForestEngine(est, n_shards=2) as eng:
        p1 = eng.predict(X[:10])
        gen = eng.swap_estimator(est2)
        assert gen == 1 and eng.stats.swaps == 1
        # swap re-partitions the NEW forest (7 trees over 2 shards)
        assert sum(eng.shard_sizes) == 7
        p2 = eng.predict(X[:10])
        np.testing.assert_allclose(p2, est2.predict(X[:10]), rtol=1e-5)
        assert not np.allclose(p1, p2)


# ------------------------------------------------------------- mesh placement

def test_mesh_placement_subprocess(fitted):
    """shard_map over a real 2-device tree mesh (forced host devices) must
    match the oracle; in-process we can't change the device count."""
    code = """
import numpy as np
from repro.core.forest import ExtraTreesRegressor
from repro.serve import ShardedForestEngine

rng = np.random.default_rng(3)
X = rng.lognormal(1.0, 1.5, size=(32, 10)).astype(np.float32)
y = np.log(2 * X[:, 0] + 0.5 * X[:, 3] + 3.0)
est = ExtraTreesRegressor(n_estimators=6, max_depth=5, seed=0).fit(X, y)
with ShardedForestEngine(est, n_shards=2, cache_size=0) as eng:
    assert eng.placement == "mesh", eng.placement
    pred = eng.predict(X)
oracle = est.predict(X)
rel = np.max(np.abs(pred - oracle) / np.maximum(np.abs(oracle), 1e-9))
assert rel <= 1e-5, rel
print("MESH_OK", rel)
"""
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300,
        env={**os.environ, "PYTHONPATH": src,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert proc.returncode == 0, proc.stderr
    assert "MESH_OK" in proc.stdout
