"""The docs system is CHECKED, not aspirational: tools/check_docs.py is a
blocking CI lane (link resolution + fenced-python compilation), and the
docs tree keeps its structural invariants — the index reaches every page,
the old monolith redirects, the README quickstart compiles."""
import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def run(*files) -> tuple[int, list[str]]:
    problems = []
    for f in files:
        problems += check_docs.check_file(Path(f))
    return (1 if problems else 0), problems


# ---------------------------------------------------------------- checker

def test_repo_docs_are_clean():
    files = check_docs.default_files()
    assert REPO / "README.md" in files
    assert len(files) >= 9          # README + the docs/ tree
    rc, problems = run(*files)
    assert rc == 0, "\n".join(problems)


def test_broken_relative_link_fails(tmp_path):
    md = tmp_path / "page.md"
    md.write_text("see [here](not_there.md) for details\n")
    rc, problems = run(md)
    assert rc == 1
    assert "broken link" in problems[0] and "not_there.md" in problems[0]


def test_anchor_stripped_and_external_skipped(tmp_path):
    (tmp_path / "other.md").write_text("# t\n")
    md = tmp_path / "page.md"
    md.write_text("[a](other.md#some-section) [b](https://example.com/x) "
                  "[c](mailto:x@y.z)\n")
    rc, problems = run(md)
    assert rc == 0, problems


def test_python_block_must_compile(tmp_path):
    md = tmp_path / "page.md"
    md.write_text("```python\ndef f(:\n```\n")
    rc, problems = run(md)
    assert rc == 1
    assert "does not compile" in problems[0]


def test_top_level_await_is_legal_in_docs(tmp_path):
    md = tmp_path / "page.md"
    md.write_text("```python\nval = await fe.rpc(x)\n```\n")
    rc, problems = run(md)
    assert rc == 0, problems


def test_non_python_fences_ignored(tmp_path):
    md = tmp_path / "page.md"
    md.write_text("```\nthis is an ascii diagram ───►\n```\n"
                  "```bash\nPYTHONPATH=src python -m pytest -x -q\n```\n")
    rc, problems = run(md)
    assert rc == 0, problems


def test_links_inside_code_blocks_not_link_checked(tmp_path):
    md = tmp_path / "page.md"
    md.write_text("```\na[0](see_elsewhere.md)\n```\n")
    rc, problems = run(md)
    assert rc == 0, problems


# ----------------------------------------------------------- docs tree

DOCS = sorted((REPO / "docs").glob("*.md"))
PAGES = [p.name for p in DOCS]


def test_docs_tree_has_the_required_pages():
    for required in ("index.md", "engine.md", "scheduling.md", "cluster.md",
                     "transport.md", "observability.md", "portability.md",
                     "paper_map.md", "serving.md"):
        assert required in PAGES


def test_index_links_every_page():
    index = (REPO / "docs" / "index.md").read_text()
    for page in PAGES:
        if page == "index.md":
            continue
        assert f"({page})" in index, f"docs/index.md does not link {page}"


def test_serving_stub_redirects_not_duplicates():
    stub = (REPO / "docs" / "serving.md").read_text()
    assert len(stub.splitlines()) < 40       # a stub, not a second copy
    for page in ("index.md", "engine.md", "transport.md", "portability.md"):
        assert f"({page})" in stub


def test_readme_links_docs_and_carries_bench_numbers():
    readme = (REPO / "README.md").read_text()
    assert "(docs/index.md)" in readme
    assert "(docs/paper_map.md)" in readme
    assert "BENCH_results.json" in readme
    # the paper's headline ranges, quoted for comparison
    assert "8.86" in readme and "1.84" in readme


@pytest.mark.parametrize("fact,page", [
    # drift tripwires: these doc claims are checked against the code
    ("`metrics`", "transport.md"),      # op list includes the scrape op
    ("`hello`", "transport.md"),        # ... and the handshake op
    ("min(max_v, 3)", "transport.md"),  # negotiation rule as shipped
    ("CLEARTEXT", "transport.md"),      # pre-TLS token warning survives
    ("portability.coldstart", "portability.md"),
])
def test_doc_facts_present(fact, page):
    assert fact in (REPO / "docs" / page).read_text()


def test_transport_doc_op_list_matches_server_dispatch():
    """The six ops remote.py actually dispatches must each be documented
    in transport.md — the drift this PR fixed stays fixed."""
    src = (REPO / "src/repro/cluster/remote.py").read_text()
    ops = set(re.findall(r'op == "(\w+)"', src))
    assert ops == {"predict", "schedule", "hello", "info", "metrics",
                   "ping"}
    doc = (REPO / "docs" / "transport.md").read_text()
    for op in ops:
        assert f"`{op}`" in doc, f"transport.md missing op `{op}`"
