"""TransferSupervisor: the self-managing cold-start tier.

Contracts under test: measured samples flow store -> predictor -> live
MAPE gauge without operator code; graduation swaps a fitted forest into
the live pool slot atomically (no request lost, generation monotone);
re-targeting replays history mid-serve; probe budgeting is deterministic
across interpreters; every exported metric scrapes with a pinned
Prometheus type.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core.dataset import DatasetStore, Sample
from repro.core.devices import TPU_V5E
from repro.core.features import N_FEATURES
from repro.core.transfer import TransferConfig, TransferPredictor
from repro.obs.calibration import CalibrationMonitor
from repro.obs.registry import MetricsRegistry
from repro.serve.engine import EngineConfig, ForestEngine, MultiDeviceEngine
from repro.serve.supervise import (PAPER_ENVELOPE_PCT, GraduatedEngine,
                                   SupervisorConfig, TransferSupervisor)

SRC = str(Path(__file__).resolve().parents[1] / "src")

ECONF = EngineConfig(backend="tree-walk", cache_size=0)


def _rows(device, n: int, seed: int):
    """Small synthetic (X, y) in the transfer feature layout (matches
    tests/test_transfer.py's ground-truth helper)."""
    from repro.core.simulate import WorkloadSpec, simulate_time_median_us

    rng = np.random.default_rng(seed)
    X, y = [], []
    for _ in range(n):
        flops = 10 ** rng.uniform(8, 11)
        gvol = 10 ** rng.uniform(6, 9)
        work = 10 ** rng.uniform(3, 6)
        spec = WorkloadSpec(flops=flops, hbm_bytes=gvol, collective_bytes=0.0,
                            special_ops=0.0, control_ops=0.0, work_items=work)
        t, _ = simulate_time_median_us(spec, device, rng)
        row = np.zeros(N_FEATURES)
        row[0] = work
        row[1] = 1.0
        row[2] = flops
        row[3] = flops
        row[8] = gvol
        row[11] = flops / max(gvol, 1.0)
        X.append(row)
        y.append(t)
    return np.stack(X), np.asarray(y)


def _samples(X, y, device: str, start: int = 0) -> list[Sample]:
    return [Sample(app="t", kernel=f"k{start + i}", variant="s",
                   features=X[i],
                   targets={device: {"time_us": float(y[i])}})
            for i in range(len(y))]


def _supervised(dev: str = "new-chip", *, pool=None, multi_engine=None,
                config: SupervisorConfig | None = None,
                tconfig: TransferConfig | None = None, registry=None):
    mon = CalibrationMonitor(registry, alpha=0.5, min_samples=4)
    tp = TransferPredictor(dev, monitor=mon, config=tconfig)
    store = DatasetStore()
    sup = TransferSupervisor(store, mon, pool=pool, multi_engine=multi_engine,
                             config=config, registry=registry)
    sup.manage(tp, replica=None if pool is None else "cold", key=dev)
    return sup, tp, store, mon


# --------------------------------------------------------------- metric kinds

def test_refresh_metrics_pinned_kinds():
    """Regression: both refresher version marks scrape as gauges (the
    failed_version mark was previously not exported at all)."""
    from repro.serve.refresh import EngineRefresher

    est = TransferPredictor(TPU_V5E)
    X, y = _rows(TPU_V5E, 16, seed=0)
    est.calibrate((X, y))
    engine = ForestEngine(est.to_forest(), ECONF)
    ref = EngineRefresher(DatasetStore(), engine, fit_fn=lambda ds: None)
    reg = MetricsRegistry()
    ref.register_metrics(reg)
    text = reg.render_prometheus()
    for name in ("last_version", "failed_version"):
        assert f"# TYPE repro_refresh_{name} gauge" in text, text
        assert f"repro_refresh_{name} -1" in text
    for name in ("refreshes", "skipped", "drift_skipped",
                 "drift_refreshes", "errors"):
        assert f"# TYPE repro_refresh_{name} counter" in text, text
    engine.close()


def test_supervisor_metrics_pinned_kinds():
    reg = MetricsRegistry()
    sup, _tp, _store, _mon = _supervised(registry=reg)
    text = reg.render_prometheus()
    for name in ("polls", "ingested", "feedback", "graduations",
                 "retargets", "alerts", "errors"):
        assert f"# TYPE repro_supervisor_{name} counter" in text, text
    for name in ("last_store_version", "devices", "graduated_devices",
                 "envelope_exceeded"):
        assert f"# TYPE repro_supervisor_{name} gauge" in text, text
    assert "repro_supervisor_devices 1" in text


# -------------------------------------------------------------- feedback loop

def test_feedback_closes_the_loop_into_live_mape():
    """Store samples -> supervise_once -> predictor observed them and the
    calibration gauge holds real serving error, no operator code."""
    sup, tp, store, mon = _supervised()
    assert mon.mape("new-chip", "time_us") is None
    X, y = _rows(TPU_V5E, 12, seed=1)
    store.extend(_samples(X, y, "new-chip"))
    out = sup.supervise_once()
    assert out["ingested"] == 12
    assert tp.stats_snapshot().n_observed == 12
    assert mon.mape("new-chip", "time_us") is not None
    snap = sup.stats_snapshot()
    assert snap["stats"].ingested == 12
    assert snap["stats"].last_store_version == store.version
    # quiet cycle: nothing new, nothing ingested
    assert sup.supervise_once()["ingested"] == 0


def test_supervisor_survives_poisoned_sample():
    """A malformed sample in the store is skipped (counted on the
    predictor), never crashes the loop, never loses the tail."""
    sup, tp, store, _mon = _supervised()
    X, y = _rows(TPU_V5E, 8, seed=2)
    good = _samples(X, y, "new-chip")
    good[3] = Sample(app="t", kernel="bad", variant="s",
                     features=np.ones(3),     # wrong width
                     targets={"new-chip": {"time_us": 1.0}})
    store.extend(good)
    out = sup.supervise_once()
    assert out["ingested"] == 7
    st = tp.stats_snapshot()
    assert st.n_observed == 7 and st.ingest_errors == 1
    assert sup.stats_snapshot()["stats"].errors == 0


# ----------------------------------------------------------------- graduation

def _cliff_rows(n: int, seed: int):
    X, y = _rows(TPU_V5E, n, seed)
    y = np.where(X[:, 11] > 100.0, 8.0 * y, y)
    return X, y


def test_graduation_under_live_traffic():
    """The tentpole end to end: transfer tier serves behind the frontend,
    measured samples stream in, the supervisor graduates mid-traffic —
    zero requests lost, slot generation bumps exactly once, the graduated
    engine answers finite positive microseconds."""
    from repro.cluster.frontend import ClusterFrontend
    from repro.cluster.replicas import ReplicaPool

    dev = "new-chip"
    mon = CalibrationMonitor(alpha=0.5, min_samples=4)
    tp = TransferPredictor(dev, monitor=mon)
    store = DatasetStore()
    pool = ReplicaPool({"cold": tp}, check_interval_s=60.0)
    sup = TransferSupervisor(
        store, mon, pool=pool,
        config=SupervisorConfig(min_graduate_samples=16, plateau_window=2,
                                engine_config=ECONF))
    sup.manage(tp, replica="cold", key=dev)

    X, y = _cliff_rows(48, seed=3)
    Xq = X[:8]
    stop = threading.Event()
    served: list[int] = []
    errs: list[BaseException] = []

    with ClusterFrontend(pool, max_queue=64) as fe:
        def traffic():
            try:
                while not stop.is_set():
                    out = fe.predict(Xq)
                    assert np.isfinite(out).all() and (out > 0).all()
                    served.append(len(out))
            except BaseException as e:  # pragma: no cover - fails the test
                errs.append(e)

        t = threading.Thread(target=traffic)
        t.start()
        try:
            for i in range(0, len(y), 4):
                store.extend(_samples(X[i:i + 4], y[i:i + 4], dev, start=i))
                sup.supervise_once()
        finally:
            stop.set()
            t.join(timeout=30)
        assert not errs
        assert served and sum(served) == 8 * len(served)  # nothing dropped

        snap = sup.stats_snapshot()
        st = snap["devices"][dev]
        assert st["stage"] == "forest"
        assert st["slot_generation"] == 1
        assert snap["stats"].graduations == 1
        assert st["graduated_at_n"] >= 16
        assert pool.stats_snapshot().slot_swaps == 1
        # the slot now serves the forest, still in linear microseconds
        out = fe.predict(Xq)
        assert np.isfinite(out).all() and (out > 0).all()
        # post-graduation samples keep scoring the forest in the SAME gauge
        before = mon.series()[(dev, "time_us")][1]
        store.extend(_samples(X[:4], y[:4], dev, start=100))
        assert sup.supervise_once()["feedback"] == 4
        assert mon.series()[(dev, "time_us")][1] == before + 4
    # graduating twice is a caller error
    with pytest.raises(ValueError):
        sup.graduate(dev)


def test_graduated_engine_is_exp_of_forest():
    X, y = _rows(TPU_V5E, 20, seed=4)
    tp = TransferPredictor(TPU_V5E)
    tp.calibrate((X, y))
    engine = ForestEngine(tp.to_forest(), ECONF)
    g = GraduatedEngine(engine)
    np.testing.assert_allclose(g.predict(X[:5]),
                               np.exp(engine.predict(X[:5])), rtol=1e-6)
    assert g.n_features == N_FEATURES
    assert g.generation == engine.generation
    g.close()


def test_graduation_admits_device_into_pricing_matrix():
    """A graduating time-target device enters MultiDeviceEngine so the
    scheduler prices it; log_time=True frontends take the raw log-target
    forest, and a second graduation of the same name is rejected."""
    Xf, yf = _rows(TPU_V5E, 24, seed=5)
    fit = TransferPredictor(TPU_V5E)
    fit.calibrate((Xf, yf))
    multi = MultiDeviceEngine(
        {"tpu-v5e": {"time_us": ForestEngine(fit.to_forest(), ECONF),
                     "power_w": None}}, log_time=True)

    sup, tp, store, _mon = _supervised(
        multi_engine=multi,
        config=SupervisorConfig(min_graduate_samples=8, plateau_window=2,
                                engine_config=ECONF))
    X, y = _rows(TPU_V5E, 16, seed=6)
    store.extend(_samples(X, y, "new-chip"))
    sup.supervise_once()
    sup.graduate("new-chip")
    assert "new-chip" in multi.device_names
    t_matrix, _p = multi.price(X[:4])
    assert t_matrix.shape == (4, 2)
    assert np.isfinite(t_matrix).all()
    # the admitted engine is log-target, matching log_time=True
    with pytest.raises(ValueError):
        multi.add_device("new-chip", multi.engines["new-chip"]["time_us"])


# ----------------------------------------------------------------- re-target

def test_retarget_mid_serve_replays_history():
    """announce_spec + supervise_once: the real spec sheet lands mid-serve,
    the predictor re-targets and the store's FULL history replays onto the
    new prior while another thread keeps appending samples."""
    sup, tp, store, mon = _supervised("mystery")
    real_spec = dataclasses.replace(TPU_V5E, name="mystery")
    X, y = _rows(real_spec, 24, seed=7)
    store.extend(_samples(X[:12], y[:12], "mystery"))
    sup.supervise_once()
    assert tp.stats_snapshot().n_observed == 12
    assert tp.device.clazz == "unknown"        # still the generic prior

    sup.announce_spec("mystery", real_spec)
    stop = threading.Event()

    def appender():
        for i in range(12, 24):
            store.extend(_samples(X[i:i + 1], y[i:i + 1], "mystery", start=i))
            if stop.wait(0.001):  # pragma: no cover - stopped early
                return

    t = threading.Thread(target=appender)
    t.start()
    try:
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            sup.supervise_once()
            st = tp.stats_snapshot()
            if st.n_observed == 24 and not t.is_alive():
                break
        t.join(timeout=30)
    finally:
        stop.set()
    sup.supervise_once()                       # drain any final append
    st = tp.stats_snapshot()
    assert tp.device.clazz == "server"         # re-targeted to the real spec
    assert st.n_observed == 24                 # full history, nothing lost
    assert sup.stats_snapshot()["stats"].retargets == 1
    # a graduated device cannot be re-targeted
    sup.graduate("mystery")
    with pytest.raises(ValueError):
        sup.announce_spec("mystery", real_spec)


# -------------------------------------------------------------------- alerts

def test_envelope_alerts_count_entering_edges_only():
    sup, _tp, _store, mon = _supervised()
    for _ in range(6):
        mon.record("other-chip", "time_us", 1.0, 10.0)   # 90% error
    assert sup.supervise_once()["alerts"]
    assert sup.stats_snapshot()["stats"].alerts == 1
    assert sup.supervise_once()["alerts"] == []          # still violating
    assert sup.stats_snapshot()["stats"].alerts == 1
    # recover (EWMA alpha=0.5 decays fast), then violate again -> new edge
    for _ in range(12):
        mon.record("other-chip", "time_us", 10.0, 10.0)
    assert mon.over_threshold(PAPER_ENVELOPE_PCT) == []
    sup.supervise_once()
    for _ in range(6):
        mon.record("other-chip", "time_us", 1.0, 10.0)
    assert sup.supervise_once()["alerts"]
    assert sup.stats_snapshot()["stats"].alerts == 2


# ------------------------------------------------------------- probe planning

def test_plan_probes_policies():
    sup, _tp, _store, mon = _supervised("chip-a")
    tp_b = TransferPredictor("chip-b", monitor=mon)
    sup.manage(tp_b, key="chip-b")
    X, y = _rows(TPU_V5E, 12, seed=8)
    # chip-a has observations + a bad gauge; chip-b is unmeasured
    for _ in range(4):
        mon.record("chip-a", "time_us", 1.0, 2.0)
    for i in range(4):
        sup._devices["chip-a"].predictor.observe(X[i], float(y[i]))

    pool_X = X
    plan_m = sup.plan_probes(pool_X, 6, policy="highest-mape")
    plan_c = sup.plan_probes(pool_X, 6, policy="coverage")
    assert len(plan_m) == len(plan_c) == 6
    # highest-mape: the unmeasured chip-b ranks worst, so it leads
    assert plan_m[0][0] == "chip-b"
    # coverage: chip-b (0 observations) gets the first 4 slots
    assert [d for d, _ in plan_c[:4]] == ["chip-b"] * 4
    # within a device, rows follow the select_probes prefix from its count
    from repro.core.transfer import select_probes
    order = list(select_probes(pool_X, len(pool_X)))
    rows_b = [r for d, r in plan_m if d == "chip-b"]
    assert rows_b == order[:len(rows_b)]
    rows_a = [r for d, r in plan_m if d == "chip-a"]
    assert rows_a == order[4:4 + len(rows_a)]     # continues past observed
    # the whole plan is exhaustible and bounded by the pool
    assert len(sup.plan_probes(pool_X, 10_000)) <= 2 * len(pool_X)
    with pytest.raises(ValueError):
        sup.plan_probes(pool_X, 4, policy="nope")
    with pytest.raises(ValueError):
        SupervisorConfig(probe_policy="nope")


_PLAN_SCRIPT = """
import sys; sys.path.insert(0, {src!r})
import numpy as np
from repro.core.transfer import TransferPredictor
from repro.core.dataset import DatasetStore
from repro.obs.calibration import CalibrationMonitor
from repro.serve.supervise import SupervisorConfig, TransferSupervisor

mon = CalibrationMonitor(alpha=0.5, min_samples=2)
sup = TransferSupervisor(DatasetStore(), mon)
rng = np.random.default_rng(5)
X = rng.lognormal(1.0, 2.0, size=(40, 12))
for name in ("zeta", "alpha", "mid"):
    tp = TransferPredictor(name, monitor=mon)
    sup.manage(tp, key=name)
for _ in range(4):
    mon.record("mid", "time_us", 1.0, 3.0)
    mon.record("zeta", "time_us", 1.0, 1.5)
for pol in ("highest-mape", "coverage"):
    plan = sup.plan_probes(X, 17, policy=pol)
    print(pol, ";".join(f"{{d}}:{{r}}" for d, r in plan))
"""


def _plan_in_subprocess(hashseed: str) -> str:
    env = dict(os.environ)
    env["PYTHONHASHSEED"] = hashseed
    out = subprocess.run(
        [sys.executable, "-c", _PLAN_SCRIPT.format(src=SRC)],
        capture_output=True, text=True, env=env, timeout=120)
    assert out.returncode == 0, out.stderr
    return out.stdout.strip()


def test_plan_probes_identical_across_hash_seeds():
    """Two hosts planning the same fleet state produce the same probe
    schedule, whatever their interpreter hash salt — same guarantee the
    probe selector itself makes."""
    a = _plan_in_subprocess("0")
    b = _plan_in_subprocess("4242")
    assert a and a == b


# ------------------------------------------------------------------ lifecycle

def test_background_loop_reacts_to_chunks():
    sup, tp, store, _mon = _supervised()
    X, y = _rows(TPU_V5E, 8, seed=9)
    with sup:
        store.extend(_samples(X, y, "new-chip"))
        sup.on_chunk(store.version, 8)      # the add_on_chunk wiring
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            if tp.stats_snapshot().n_observed == 8:
                break
            time.sleep(0.01)
    assert tp.stats_snapshot().n_observed == 8
    assert sup.stats_snapshot()["stats"].polls >= 1
    # idempotent stop, restartable start
    sup.stop()
    sup.start()
    sup.stop()


def test_manage_validation():
    from repro.cluster.replicas import ReplicaPool

    tp = TransferPredictor("new-chip")
    pool = ReplicaPool({"cold": tp}, check_interval_s=60.0)
    mon = CalibrationMonitor()
    sup = TransferSupervisor(DatasetStore(), mon, pool=pool)
    with pytest.raises(KeyError):
        sup.manage(tp, replica="nope")
    sup.manage(tp, replica="cold")
    with pytest.raises(ValueError):
        sup.manage(tp, replica="cold")      # duplicate key
    pool.close()
