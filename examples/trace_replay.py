"""Trace replay end to end: record a mixed-tenant trace, replay it against
an in-process cluster frontend, and print the per-tenant outcome report.

    PYTHONPATH=src python examples/trace_replay.py [--wire]

The script generates a 10-second mixed-tenant trace (an interactive tenant
with tight deadlines, a batch tenant with none, a best-effort tenant pinned
to a low priority), serializes it to the CRC-tagged JSONL format, reloads
it — the round trip is the point: what gets replayed is the ARTIFACT, not
in-memory state — and drives a demo frontend at recorded timestamps with
open-loop pacing. With ``--wire`` the same trace is replayed a second time
against a ``repro.cluster`` server SUBPROCESS over loopback TCP (the PR-4
wire), showing that the replayer drives both target shapes unchanged.

The final lines print each tenant's served/shed/expired counts, observed
wall-clock percentiles, and the deterministic outcome digest — the same
digest the golden-trace regression test pins across interpreters.
"""
import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cluster.remote import demo_frontend, spawn_demo_server  # noqa: E402
from repro.workloads.trace import (TraceReplayer, dump_trace,  # noqa: E402
                                   gen_tenant_mix, load_trace,
                                   synthetic_catalog)

N_FEATURES = 12


def record_trace(path: Path):
    ids, X = synthetic_catalog(32, N_FEATURES, seed=5)
    trace = gen_tenant_mix(
        ids, X, duration_s=10.0, seed=17,
        tenants={
            "interactive": {"rate": 25.0, "deadline_band": (0.3, 1.5)},
            "batch": {"rate": 15.0, "deadline_band": None},
            "best-effort": {"rate": 10.0, "deadline_band": (2.0, 6.0),
                            "priority": 9},
        })
    dump_trace(trace, path)
    print(f"recorded {len(trace)} events / {trace.duration_s():.1f}s "
          f"/ {len(trace.tenants())} tenants -> {path}")
    return path


def print_report(label: str, rep) -> None:
    print(f"\n[{label}] pacing={rep.pacing} speed={rep.speed:g} "
          f"wall={rep.wall_s:.2f}s digest={rep.digest()[:16]}")
    print(f"  {'tenant':<14}{'submitted':>10}{'served':>8}{'shed':>6}"
          f"{'expired':>8}{'retries':>8}{'p50 ms':>9}{'p99 ms':>9}")
    for tenant, s in sorted(rep.per_tenant.items()):
        print(f"  {tenant:<14}{s.submitted:>10}{s.served:>8}{s.shed:>6}"
              f"{s.expired:>8}{s.retries:>8}"
              f"{s.wall_percentile_ms(50):>9.2f}"
              f"{s.wall_percentile_ms(99):>9.2f}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--wire", action="store_true",
                    help="also replay over loopback TCP against a server "
                         "subprocess")
    ap.add_argument("--speed", type=float, default=4.0,
                    help="replay speedup over recorded time (default 4x)")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as tmp:
        trace = load_trace(record_trace(Path(tmp) / "demo.jsonl"))

    fe = demo_frontend(seed=3, n_features=N_FEATURES).start()
    try:
        rep = TraceReplayer(fe, pacing="open", speed=args.speed).replay(trace)
    finally:
        fe.close()
    print_report("in-process frontend", rep)

    if args.wire:
        from repro.cluster import RemoteReplica

        proc, host, port = spawn_demo_server(seed=3, n_features=N_FEATURES)
        try:
            replica = RemoteReplica((host, port), timeout_s=30.0)
            rep = TraceReplayer(replica, pacing="open",
                                speed=args.speed).replay(trace)
            replica.close()
        finally:
            proc.terminate()
            proc.wait(timeout=10)
        print_report("over the PR-4 wire", rep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
