"""Streaming serving loop: collect -> snapshot -> refit -> hot-swap, live.

The one-shot flow (collect() -> fit() -> ForestEngine) cannot ingest new
ground truth. This demo runs the full streaming stack instead:

  StreamingCollector (background thread, measures workloads incrementally)
      └─> DatasetStore (versioned, deterministic over-representation cap)
            └─> EngineRefresher (background thread: refit on each snapshot,
                  atomically hot-swap into the LIVE engines)
                    └─> ForestEngine / ShardedForestEngine serving a
                          concurrent prediction stream the whole time

Every answered batch is generation-uniform even while swaps land mid-storm.

    PYTHONPATH=src python examples/streaming_serve.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main():
    from repro.core.dataset import DatasetStore
    from repro.serve import (EngineRefresher, ForestEngine,
                             ShardedForestEngine, single_device_fit_fn)
    from repro.workloads.stream import StreamingCollector, iter_samples
    from repro.workloads.suite import suite

    device = "tpu-v5e"
    workloads = suite(sizes=("s",))
    store = DatasetStore(max_per_group=100, seed=0)

    print(f"== bootstrap: measure the first workloads ({device}) ==")
    bootstrap, rest = workloads[:24], workloads[24:]
    store.extend(list(iter_samples(bootstrap, repeats=3, measure_cpu=False,
                                   seed=0)))
    fit = single_device_fit_fn(device, n_estimators=32)
    snap = store.snapshot()
    eng = ForestEngine(fit(snap.dataset), backend="flat-numpy", max_batch=32)
    print(f"   store v{snap.version}: {len(snap.dataset)} samples, "
          f"serving generation {eng.generation}")

    print("== stream the rest while serving ==")
    X0, _, _ = snap.dataset.matrix(device, "time_us")
    X0 = X0.astype(np.float32)
    collector = StreamingCollector(store, rest, repeats=3, measure_cpu=False,
                                   seed=0, chunk_size=16)
    refresher = EngineRefresher(store, eng, fit, poll_s=0.02)
    served = 0
    deadline = time.monotonic() + 300           # bound the demo loop: a
    with collector, refresher:                  # blacklisted final refit
        while time.monotonic() < deadline:      # must not hang it
            caught_up = refresher.stats.last_version >= store.version
            gave_up = refresher.stats.failed_version == store.version
            if collector.done.is_set() and (caught_up or gave_up):
                break
            futs = [eng.predict_async(X0[i % X0.shape[0]])
                    for i in range(16)]
            for f in futs:
                f.result(timeout=30)
            served += len(futs)
            time.sleep(0.01)
            if served % 320 == 0:
                print(f"   served={served:5d}  store v{store.version} "
                      f"({len(store)} samples)  generation={eng.generation}  "
                      f"hit_rate={eng.stats.hit_rate():.2f}")
    print(f"   final: {len(store)} samples, store v{store.version}, "
          f"{refresher.stats.refreshes} refreshes, "
          f"engine generation {eng.generation}")
    s = eng.stats
    print(f"   engine: {s.requests} requests, {s.batches} forest calls, "
          f"hit_rate={s.hit_rate():.2f}, swaps={s.swaps}")
    eng.close()

    print("== same data, tree-axis partitioned (ShardedForestEngine) ==")
    from repro.core.forest import ExtraTreesRegressor
    Xs, ys, _ = store.snapshot().dataset.matrix(device, "time_us")
    # cap tree depth below the dense embedding depth so the partitioned
    # prediction is exact (deeper forests get the documented bounded
    # truncation of the dense layout)
    est = ExtraTreesRegressor(n_estimators=32, max_depth=8, seed=0).fit(
        Xs.astype(np.float32), np.log(ys))
    oracle = est.predict(X0[:16])
    with ShardedForestEngine(est, n_shards=2) as sh:
        pred = sh.predict(X0[:16])
        rel = np.max(np.abs(pred - oracle) / np.maximum(np.abs(oracle), 1e-9))
        print(f"   backend={sh.backend} placement={sh.placement} "
              f"shards={sh.shard_sizes} max_rel_err_vs_oracle={rel:.1e}")
        print("   (run under XLA_FLAGS=--xla_force_host_platform_device_count=4"
              " to see the shard_map mesh placement)")
    print("done.")


if __name__ == "__main__":
    main()
