"""Quickstart: the paper's full pipeline in one file.

 1. take a handful of JAX compute kernels (from the workload suite),
 2. extract hardware-independent features from their StableHLO (recorded
    once — the portability property),
 3. measure ground-truth wall time on THIS machine (cpu-host),
 4. train the Extremely Randomized Trees model,
 5. predict held-out kernels and report MAPE + single-prediction latency.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.forest import ExtraTreesRegressor, predict_flat
from repro.core.metrics import mape
from repro.core.split import time_stratified_kfold
from repro.workloads.collect import collect
from repro.workloads.suite import suite


def main():
    print("collecting workloads (features once + CPU wall-clock)...")
    workloads = suite(sizes=("s", "m"))
    ds = collect(workloads, repeats=5, measure_cpu=True,
                 progress=lambda m: print(m))
    X, y, kept = ds.matrix("cpu-host", "time_us")
    print(f"dataset: {len(y)} kernels, {y.min():.0f}..{y.max():.0f} us")

    rng = np.random.default_rng(0)
    folds = time_stratified_kfold(y, 4, rng)
    scores = []
    for fold in folds:
        est = ExtraTreesRegressor(n_estimators=64, criterion="mse",
                                  max_features="max", seed=0)
        est.fit(X[fold.train].astype(np.float32), np.log(y[fold.train]))
        pred = np.exp(est.predict(X[fold.test].astype(np.float32)))
        scores.append(mape(y[fold.test], pred))
    print(f"4-fold time-prediction MAPE: median {np.median(scores):.1f}% "
          f"(paper K20: median 13.9%)")

    # prediction latency (paper Tables 4/5: 15-108 ms; our flat path: us)
    est = ExtraTreesRegressor(n_estimators=128, seed=0).fit(
        X.astype(np.float32), np.log(y))
    flat = est.to_flat()
    x1 = X[:1].astype(np.float32)
    predict_flat(flat, x1)
    t0 = time.perf_counter()
    for _ in range(50):
        predict_flat(flat, x1)
    lat = (time.perf_counter() - t0) / 50 * 1e3
    t0 = time.perf_counter()
    est.predict(x1)
    walk = (time.perf_counter() - t0) * 1e3
    print(f"single prediction: tree-walk {walk:.1f} ms (paper's path), "
          f"flat {lat:.3f} ms ({walk/lat:.0f}x)")


if __name__ == "__main__":
    main()
