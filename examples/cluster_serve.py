"""Cluster tier demo: 2-replica frontend surviving a shard AND a replica kill.

Spins up the full deployable stack —

  PersistentDatasetStore (WAL + snapshots on disk)
      └─> bootstrap fit -> two replicas (one sharded, one plain) behind a
            ReplicaPool with health checks
                └─> ClusterFrontend: bounded admission queue, deadline-aware
                      dispatch, backpressure, failover

— streams a workload of single-prediction RPCs through it, then mid-run:

  1. kills a SHARD of the sharded replica (``drop_shard``: the forest mean
     renormalizes over the surviving trees; answers keep flowing, the
     degradation is counted in the engine stats);
  2. kills a whole REPLICA (its probes/dispatches fail; the pool drains it
     and the frontend fails over to the survivor);
  3. "crashes" the dataset store and reopens it, showing recovery to the
     exact pre-crash version.

Every request in the stream is answered — no outage, only counted
degradation.

    PYTHONPATH=src python examples/cluster_serve.py
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main():
    from repro.cluster import (ClusterFrontend, PersistentDatasetStore,
                               ReplicaPool)
    from repro.core.dataset import Sample
    from repro.core.forest import ExtraTreesRegressor
    from repro.serve import ForestEngine, ShardedForestEngine

    rng = np.random.default_rng(0)
    n_features, device = 8, "tpu-v5e"

    print("== durable ground truth: PersistentDatasetStore (WAL + snapshots) ==")
    workdir = Path(tempfile.mkdtemp(prefix="cluster_serve_"))
    store = PersistentDatasetStore(workdir / "store", snapshot_every=4)

    def measure(i):
        x = rng.lognormal(1.0, 1.2, size=n_features)
        t = float(3.0 * x[0] + 0.8 * x[2] + 1.0)
        return Sample(app="demo", kernel=f"k{i % 6}", variant=f"v{i}",
                      features=x, targets={device: {"time_us": t}})

    for chunk in range(4):
        store.extend([measure(chunk * 8 + j) for j in range(8)])
    print(f"   store v{store.version}: {len(store)} samples "
          f"({len(list((workdir / 'store').glob('snapshot-*.json')))} "
          f"snapshot(s) + WAL on disk)")

    print("== fit + 2 replicas behind the frontend ==")
    snap = store.snapshot()
    X, y, _ = snap.dataset.matrix(device, "time_us")
    X = X.astype(np.float32)
    est = ExtraTreesRegressor(n_estimators=12, max_depth=6, seed=0).fit(
        X, np.log(y))
    replicas = {
        "sharded": ShardedForestEngine(est, n_shards=3, cache_size=0),
        "plain": ForestEngine(est, backend="flat-numpy", cache_size=0),
    }
    pool = ReplicaPool(replicas, check_interval_s=0.05, unhealthy_after=2)
    frontend = ClusterFrontend(pool, max_queue=128, dispatch_batch=16,
                               max_retries=2)

    oracle = np.exp(est.predict(X))
    answered, max_rel = 0, 0.0

    def stream(n, deadline_s=5.0):
        nonlocal answered, max_rel
        futs = [(i % X.shape[0],
                 frontend.submit(X[i % X.shape[0]], deadline_s=deadline_s))
                for i in range(n)]
        for row, fut in futs:
            got = np.exp(fut.result(timeout=30))
            max_rel = max(max_rel,
                          abs(got - oracle[row]) / max(oracle[row], 1e-9))
            answered += 1

    stream(64)
    print(f"   {answered} answered, healthy={pool.healthy_names()}, "
          f"p50s={ {k: f'{v:.2f}ms' for k, v in pool.p50s_ms().items()} }")

    print("== kill a SHARD mid-run (renormalized mean, no outage) ==")
    sharded = replicas["sharded"]
    lost = sharded.drop_shard(1)
    stream(64)
    s = sharded.stats
    print(f"   dropped shard 1 ({lost} trees lost, {sharded.live_trees} "
          f"serving); shard_drops={s.shard_drops} trees_lost={s.trees_lost}")
    print(f"   {answered} answered so far "
          f"(degraded replica answers differ from the full forest — that is "
          f"the counted accuracy cost)")

    print("== kill a whole REPLICA mid-run (drain + failover) ==")

    def died(X):                          # the replica process is gone: every
        raise RuntimeError("replica process died")   # RPC to it now fails

    sharded.predict = died
    t0 = time.monotonic()
    while "sharded" in pool.healthy_names() and time.monotonic() - t0 < 10:
        time.sleep(0.02)                  # health checks notice the corpse
    stream(64)
    print(f"   healthy={pool.healthy_names()} "
          f"drains={pool.stats.drains} served_by={frontend.stats.by_replica}")
    print(f"   {answered} answered; every request of the run got an answer "
          f"(served={frontend.stats.served}, failed={frontend.stats.failed}, "
          f"retries={frontend.stats.retries})")
    print(f"   plain-replica answers matched the oracle to "
          f"{max_rel:.1e} rel")

    print("== crash + recover the dataset store ==")
    pre_version, pre_len = store.version, len(store)
    store.close()                         # the "crash" (WAL survives)
    recovered = PersistentDatasetStore(workdir / "store", snapshot_every=4)
    print(f"   recovered store v{recovered.version} "
          f"({len(recovered)} samples) == pre-crash v{pre_version} "
          f"({pre_len}): {recovered.version == pre_version}")
    recovered.close()

    frontend.close()                      # joins dispatcher, health checks,
    print("done.")                        # refreshers, engine workers


if __name__ == "__main__":
    main()
