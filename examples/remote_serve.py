"""Cross-host serving demo: the scheduler lives in THIS process; predictions
come from a ``PredictionServer`` running in a SEPARATE process over
loopback TCP — and the server is killed (and restarted) mid-run.

    parent process                          server subprocess
    ──────────────                          ─────────────────
    core/scheduler.schedule(deadline_s=…)   python -m repro.cluster
        │ slack → deadline_ms on the wire       PredictionServer
        ▼                                         └─ ClusterFrontend
    ClusterFrontend ── ReplicaPool ──┬─ RemoteReplica ──(TCP)──┘ └─ engine
                                     └─ ForestEngine (local fallback)

Mid-run: ``kill -9`` the server → probes/dispatches fail retryably, the
pool DRAINS the remote member, every request fails over to the local
replica (no request lost). Restart it → probes REVIVE the member and
traffic flows across the wire again.

The wire negotiates protocol v3 at connect (binary zero-copy frames,
many in-flight requests pipelined on one socket); a v2-only peer on
either end keeps working over JSON — see docs/transport.md.

    PYTHONPATH=src python examples/remote_serve.py
"""
import socket
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def spawn_server(port: int):
    from repro.cluster.remote import spawn_demo_server
    proc, _host, _port = spawn_demo_server(port)
    return proc


def main():
    from repro.cluster import ClusterFrontend, RemoteReplica, ReplicaPool
    from repro.cluster.remote import demo_estimator
    from repro.core.scheduler import (DevicePredictor, schedule,
                                      slack_priority)
    from repro.serve import ForestEngine

    with socket.socket() as s:                 # pick a free loopback port
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]

    print("== spawn the serving host (separate process) ==")
    proc = spawn_server(port)
    print(f"   server pid {proc.pid} listening on 127.0.0.1:{port}")

    # the subprocess fit demo_estimator() with default args; fitting the
    # same seed here gives the oracle the remote answers must match
    est = demo_estimator()
    rng = np.random.default_rng(42)
    X = rng.lognormal(1.0, 1.5, size=(48, est.n_features_)).astype(np.float32)
    oracle = est.predict(X)

    local = ForestEngine(est, backend="flat-numpy", cache_size=0)
    remote = RemoteReplica("127.0.0.1", port, timeout_s=10.0,
                           connect_timeout_s=1.0)
    pool = ReplicaPool({"local": local, "remote": remote},
                       check_interval_s=0.05, unhealthy_after=2,
                       revive_after=1)
    frontend = ClusterFrontend(pool, max_queue=128, dispatch_batch=8)

    print("== remote == in-process, straight through the wire ==")
    err = float(np.max(np.abs(remote.predict(X) - oracle)))
    print(f"   max |remote - in-process| = {err:.2e} over {len(X)} rows "
          f"(negotiated protocol v{remote.negotiated_version}: binary "
          f"zero-copy frames, pipelined on one socket)")

    print("== scheduler deadline -> wire priority (no magic ints) ==")
    deadline_s = 0.5
    sched = schedule(X, [DevicePredictor("svc", frontend)],
                     deadline_s=deadline_s)
    print(f"   schedule({deadline_s}s budget): {len(sched.assignments)} "
          f"kernels priced in {sched.predict_seconds * 1e3:.1f} ms "
          f"(slack {deadline_s}s -> admission priority "
          f"{slack_priority(deadline_s)}; a 5 ms-slack caller would get "
          f"priority {slack_priority(0.005)})")

    answered = 0

    def stream(n, tag):
        nonlocal answered
        futs = [frontend.submit(X[i % len(X)], deadline_s=10.0)
                for i in range(n)]
        worst = max(abs(f.result(timeout=30) - oracle[i % len(X)])
                    for i, f in enumerate(futs))
        answered += n
        print(f"   {tag}: {n}/{n} answered (max err {worst:.2e}), "
              f"healthy={pool.healthy_names()}")

    stream(24, "both replicas up")

    print("== kill -9 the serving host mid-run ==")
    proc.kill()
    proc.wait(timeout=10)
    stream(48, "server dead")                  # failover: nothing lost
    t0 = time.monotonic()
    while "remote" in pool.healthy_names() and time.monotonic() - t0 < 10:
        time.sleep(0.02)                       # probes notice the corpse
    print(f"   remote member drained (drains={pool.stats.drains}, "
          f"probe_failures={pool.stats.probe_failures})")

    print("== restart the serving host on the same port ==")
    proc = spawn_server(port)
    t0 = time.monotonic()
    while ("remote" not in pool.healthy_names()
           and time.monotonic() - t0 < 30):
        time.sleep(0.05)                       # probes revive the member
    print(f"   revived after {time.monotonic() - t0:.1f}s "
          f"(revivals={pool.stats.revivals}, "
          f"reconnects={remote.stats.connects})")
    stream(24, "server back")

    print("== outcome ==")
    print(f"   every request of the run was answered: {answered} served, "
          f"{frontend.stats.failed} failed, {frontend.stats.retries} "
          f"failovers, served_by={frontend.stats.by_replica}")
    frontend.close()                           # joins the whole tier
    proc.kill()
    proc.wait(timeout=10)
    print("done.")


if __name__ == "__main__":
    main()
