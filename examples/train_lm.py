"""End-to-end training driver (deliverable b): train a smollm-family model
for a few hundred steps with the full framework stack — sharded train step,
background data pipeline, async checkpointing with crash-resume, and the
predictor-backed straggler monitor.

CPU-sized by default (reduced config, ~1.5M params); pass --full-width to
train the real 360M config (slow on CPU). Re-running the script resumes
from the latest checkpoint automatically.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--full-width", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models.registry import build_model
    from repro.runtime.monitor import StepMonitor
    from repro.train.loop import TrainLoopConfig, run_training
    from repro.train.optimizer import OptConfig

    cfg = ARCHS["smollm-360m"]
    if not args.full_width:
        cfg = reduced(cfg, layers=4, d_model=128, vocab=512)
    model = build_model(cfg)
    print(f"arch {cfg.name}: {model.n_params():,} params")

    monitor = StepMonitor(straggler_factor=3.0,
                          on_straggler=lambda e: print(f"  straggler! {e}"))
    out = run_training(
        model, make_host_mesh(),
        TrainLoopConfig(steps=args.steps, batch=args.batch,
                        seq_len=args.seq_len, checkpoint_dir=args.ckpt,
                        checkpoint_every=100, log_every=25),
        opt_cfg=OptConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5)),
        monitor=monitor)
    losses = out["losses"]
    if losses:
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({len(losses)} steps this run"
              + (f", resumed from {out['resumed_from']}" if out["resumed_from"]
                 else "") + ")")
    print(f"median step {1e3*np.median([s for _, s in monitor.history]):.0f} ms;"
          f" stragglers flagged: {len(monitor.flagged)}")


if __name__ == "__main__":
    import numpy as np
    main()
