"""Cold-start: serve a device the model has NEVER been trained on.

The paper's features are hardware-independent (§3.1), so they exist before
the first measurement on a new device — only the labels are missing. This
demo (docs/portability.md) stages the full story:

 1. an `edge-dvfs` card shows up with NO spec sheet and NO training data;
    `build_transfer_engine` serves it IMMEDIATELY behind a ClusterFrontend
    (generic analytical prior),
 2. probe measurements arrive in feature-coverage order (`select_probes`)
    and the hybrid analytical+forest-residual model converges, racing a
    static AnalyticalBaseline that KNOWS the spec sheet,
 3. a live StreamingCollector feeds late measurements through a
    DatasetStore (`ingest_store`) while the frontend keeps serving, with
    the CalibrationMonitor's `calibration.mape` gauge as the live curve,
 4. the device graduates: `to_forest()` → a standalone per-device forest.

    PYTHONPATH=src python examples/coldstart_transfer.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

DEVICE = "edge-dvfs"


def main():
    from repro.cluster import ClusterFrontend, ReplicaPool
    from repro.core.devices import DEVICE_MODELS
    from repro.core.metrics import mape
    from repro.core.simulate import AnalyticalBaseline
    from repro.core.transfer import generic_device_prior, select_probes
    from repro.obs.calibration import CalibrationMonitor
    from repro.obs.registry import MetricsRegistry
    from repro.serve import build_transfer_engine
    from repro.workloads.collect import load_or_collect

    ds = load_or_collect(fast=True, progress=lambda *_: None)
    ds = ds.reduce_overrepresented()
    X, y, _ = ds.matrix(DEVICE, "time_us")
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    ev, pool = perm[:60], perm[60:]
    Xev, yev, Xp, yp = X[ev], y[ev], X[pool], y[pool]

    print(f"== day zero: '{DEVICE}' arrives, spec sheet UNKNOWN ==")
    reg = MetricsRegistry()
    mon = CalibrationMonitor(reg, alpha=0.3)
    cold = build_transfer_engine(generic_device_prior(DEVICE), monitor=mon)
    fe = ClusterFrontend(ReplicaPool({"cold": cold}))
    try:
        first = fe.predict(Xev[:4])
        print(f"   serving from second zero (mode={cold.mode}): "
              f"{np.array2string(first, precision=1)} us")

        am = AnalyticalBaseline(DEVICE_MODELS[DEVICE]).predict(Xev)
        am_mape = mape(yev, am)
        print(f"   static roofline that KNOWS the spec: {am_mape:5.1f}% MAPE"
              f" — the bar to clear\n")

        print("== probe campaign (feature-coverage order) ==")
        order = select_probes(Xp, 48)
        seen = 0
        for n in (1, 2, 4, 8, 16, 32, 48):
            batch = order[seen:n]
            cold.observe(Xp[batch], yp[batch])
            seen = n
            m = mape(yev, fe.predict(Xev))
            beat = " <- beats the spec-aware roofline" if m < am_mape else ""
            print(f"   n={n:3d}  mode={cold.mode:6s}  "
                  f"eval MAPE {m:6.1f}%{beat}")

        print("\n== live tail: StreamingCollector -> store -> "
              "ingest_store, mid-serve ==")
        from repro.core.dataset import DatasetStore
        from repro.workloads.stream import StreamingCollector
        from repro.workloads.suite import suite

        store = DatasetStore()
        coll = StreamingCollector(
            store, suite(sizes=("s",))[:8], repeats=2, measure_cpu=False,
            seed=11, chunk_size=4,
            on_chunk=lambda _v, _n: cold.ingest_store(store))
        coll.run_sync()
        stats = cold.stats_snapshot()
        print(f"   {stats.n_observed} samples total, "
              f"{stats.analytical_refits} analytical refits, "
              f"generation {stats.generation}")
        for row in reg.snapshot():
            if row["name"] == "calibration.mape":
                print(f"   live gauge calibration.mape{row['labels']} "
                      f"= {row['value']:.1f}%")

        print("\n== graduation: standalone per-device forest ==")
        est = cold.to_forest()
        grad = mape(yev, np.exp(est.predict(Xev.astype(np.float32))))
        print(f"   to_forest() on {stats.n_observed} observations: "
              f"{grad:5.1f}% MAPE -> hand to ForestEngine.swap_estimator")
        final = mape(yev, fe.predict(Xev))
        print(f"\ncold-start summary: prior {am_mape:.1f}% (spec-aware "
              f"static) vs hybrid {final:.1f}% after {stats.n_observed} "
              f"probes")
    finally:
        fe.close()


if __name__ == "__main__":
    main()
