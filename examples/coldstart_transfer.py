"""Cold-start: serve a device the model has NEVER been trained on.

The paper's features are hardware-independent (§3.1), so they exist before
the first measurement on a new device — only the labels are missing. This
demo (docs/portability.md) stages the full SUPERVISED story:

 1. an `edge-dvfs` card shows up with NO spec sheet and NO training data;
    `build_transfer_engine` serves it IMMEDIATELY behind a ClusterFrontend
    (generic analytical prior),
 2. a `TransferSupervisor` closes the loop: probe measurements land in a
    DatasetStore and every `supervise_once` cycle feeds them back into the
    predictor AND the `calibration.mape` gauge — no operator code,
 3. the real spec sheet arrives MID-SERVE (`announce_spec`): the
    supervisor re-targets the prior and replays the store's full history
    onto it,
 4. the tier plateaus and the supervisor auto-graduates the device:
    `to_forest()` fitted off the serving locks, the `ForestEngine` swapped
    atomically into the live `ReplicaPool` slot (generation bump, zero
    dropped requests),
 5. a live StreamingCollector shows the `add_on_chunk(sup.on_chunk)`
    wiring that pokes the supervisor the instant new truth lands.

    PYTHONPATH=src python examples/coldstart_transfer.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

DEVICE = "edge-dvfs"


def main():
    from repro.cluster import ClusterFrontend, ReplicaPool
    from repro.core.dataset import DatasetStore, Sample
    from repro.core.devices import DEVICE_MODELS
    from repro.core.metrics import mape
    from repro.core.simulate import AnalyticalBaseline
    from repro.core.transfer import (TransferConfig, generic_device_prior,
                                     select_probes)
    from repro.obs.calibration import CalibrationMonitor
    from repro.obs.registry import MetricsRegistry
    from repro.serve import EngineConfig, build_transfer_engine
    from repro.serve.supervise import SupervisorConfig, TransferSupervisor
    from repro.workloads.collect import load_or_collect

    ds = load_or_collect(fast=True, progress=lambda *_: None)
    ds = ds.reduce_overrepresented()
    X, y, _ = ds.matrix(DEVICE, "time_us")
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    ev, pool_idx = perm[:60], perm[60:]
    Xev, yev, Xp, yp = X[ev], y[ev], X[pool_idx], y[pool_idx]

    print(f"== day zero: '{DEVICE}' arrives, spec sheet UNKNOWN ==")
    reg = MetricsRegistry()
    mon = CalibrationMonitor(reg, alpha=0.3)
    cold = build_transfer_engine(
        generic_device_prior(DEVICE), monitor=mon,
        config=TransferConfig(min_samples_leaf=4, shrinkage=32.0))
    store = DatasetStore()
    pool = ReplicaPool({"cold": cold})
    sup = TransferSupervisor(
        store, mon, pool=pool, registry=reg,
        config=SupervisorConfig(
            min_graduate_samples=48, plateau_window=3,
            engine_config=EngineConfig(backend="tree-walk", cache_size=0)))
    sup.manage(cold, replica="cold", key=DEVICE)
    fe = ClusterFrontend(pool)
    try:
        first = fe.predict(Xev[:4])
        print(f"   serving from second zero (mode={cold.mode}): "
              f"{np.array2string(first, precision=1)} us")

        am = AnalyticalBaseline(DEVICE_MODELS[DEVICE]).predict(Xev)
        am_mape = mape(yev, am)
        print(f"   static roofline that KNOWS the spec: {am_mape:5.1f}% MAPE"
              f" — the bar to clear\n")

        print("== supervised probe campaign (store -> supervisor -> "
              "model) ==")
        order = select_probes(Xp, len(Xp))

        def feed(idx, start):
            store.extend([Sample(app="demo", kernel=f"k{start + k}",
                                 variant="s", features=Xp[j],
                                 targets={DEVICE:
                                          {"time_us": float(yp[j])}})
                          for k, j in enumerate(idx)])
            return sup.supervise_once()

        seen = 0
        for n in (8, 16, 24):
            out = feed(order[seen:n], seen)
            seen = n
            m = mape(yev, fe.predict(Xev))
            print(f"   n={n:3d}  mode={cold.mode:6s}  ingested="
                  f"{out['ingested']}  eval MAPE {m:6.1f}%")

        print(f"\n== the real '{DEVICE}' spec sheet lands mid-serve ==")
        sup.announce_spec(DEVICE, DEVICE_MODELS[DEVICE])
        out = feed([], seen)
        st = cold.stats_snapshot()
        print(f"   re-targeted ({out['retargeted']}), store history "
              f"replayed: n_observed={st.n_observed}, clazz="
              f"{cold.device.clazz}")

        print("\n== stream on until the tier plateaus and auto-graduates ==")
        while seen < len(order):
            out = feed(order[seen:seen + 8], seen)
            seen += 8
            stage = sup.stats_snapshot()["devices"][DEVICE]["stage"]
            if out["graduated"]:
                print(f"   n={cold.stats_snapshot().n_observed:3d}  "
                      f"GRADUATED -> ForestEngine swapped into the live "
                      f"slot")
                break
            m = mape(yev, fe.predict(Xev))
            print(f"   n={seen:3d}  stage={stage:8s}  eval MAPE {m:6.1f}%")

        snap = sup.stats_snapshot()
        dev_state = snap["devices"][DEVICE]
        m_final = mape(yev, fe.predict(Xev))
        print(f"   slot generation {dev_state['slot_generation']}, "
              f"pool slot_swaps={pool.stats_snapshot().slot_swaps}, "
              f"graduated forest eval MAPE {m_final:6.1f}%")

        print("\n== post-graduation: same gauge keeps scoring the forest ==")
        out = feed(order[:4], 9000)       # four repeat measurements
        for row in reg.snapshot():
            if row["name"] == "calibration.mape":
                print(f"   live gauge calibration.mape{row['labels']} "
                      f"= {row['value']:.1f}%  "
                      f"(+{out['feedback']} feedback samples)")

        print("\n== live collector wiring (chunk -> wake the supervisor) ==")
        from repro.workloads.stream import StreamingCollector
        from repro.workloads.suite import suite

        coll = StreamingCollector(
            store, suite(sizes=("s",))[:4], repeats=2, measure_cpu=False,
            seed=11, chunk_size=4)
        coll.add_on_chunk(sup.on_chunk)   # poke, don't poll
        with sup:                         # background supervision loop
            coll.run_sync()
            sup.stop()
        s = snap["stats"]
        print(f"   supervisor totals: ingested={s.ingested} "
              f"retargets={s.retargets} graduations={s.graduations} "
              f"alerts={s.alerts}")
        print(f"\ncold-start summary: spec-aware static {am_mape:.1f}% vs "
              f"supervised lifecycle {m_final:.1f}% — prior -> fitted -> "
              f"hybrid -> forest with no operator in the loop")
    finally:
        fe.close()


if __name__ == "__main__":
    main()
