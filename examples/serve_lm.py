"""Batched serving example: prefill a batch of prompts, then decode with
donated KV caches; reports per-token latency and throughput for two archs
(attention-cache smollm vs O(1)-state xlstm — the long-context trade).

    PYTHONPATH=src python examples/serve_lm.py --gen 48
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeConfig
    from repro.launch.serve import generate
    from repro.models.registry import build_model

    for arch in ("smollm-360m", "xlstm-125m"):
        cfg = reduced(ARCHS[arch])
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
        batch = model.make_batch(shape)
        toks, times = generate(model, params, batch, args.gen)
        med = float(np.median(times))
        print(f"{arch:14s} generated {tuple(toks.shape)}; "
              f"median decode {med*1e3:.2f} ms/token "
              f"({args.batch/med:.0f} tok/s); "
              f"cache: {'KV grows with context' if cfg.family == 'dense' else 'O(1) state'}")


if __name__ == "__main__":
    main()
