"""Predictive sharding auto-tune (the paper's model applied to the
framework's own configuration problem): lower the train step under several
named sharding strategies, extract hardware-independent features from each
partitioned program, rank by predicted step time, and VERIFY the ranking by
actually timing the candidates on this host.

    PYTHONPATH=src python examples/autotune_sharding.py
"""
import os
import sys
from pathlib import Path

# 8 virtual devices so strategies actually differ (must precede jax import)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import time

import jax
import numpy as np


def main():
    from dataclasses import replace
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeConfig
    from repro.core.autotune import autotune_strategy
    from repro.launch.cells import cell_fns
    from repro.models.registry import build_model
    from repro.sharding.context import activation_sharding
    from repro.train import init_train_state
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    cfg = replace(reduced(ARCHS["smollm-360m"]), n_layers=4, d_model=256,
                  d_ff=1024, vocab=2048)
    model = build_model(cfg)
    shape = ShapeConfig("tune", 256, 8, "train")

    result = autotune_strategy(model, shape, mesh,
                               strategies=("2d", "tp", "zero3"))
    print("predicted ranking (analytical fallback — no trained forest):")
    for name, t in result.ranked:
        print(f"  {name:8s} {t*1e3:10.3f} ms (predicted)")

    print("\nmeasured on this host:")
    measured = {}
    for strat in ("2d", "tp", "zero3"):
        fn, args, in_sh, out_sh, donate = cell_fns(model, shape, strat, mesh)
        state = init_train_state(model, jax.random.key(0))
        state = jax.device_put(state, in_sh[0])
        batch = jax.device_put(model.make_batch(shape), in_sh[1])
        with mesh, activation_sharding(mesh, strat):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            out = jitted(state, batch)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(3):
                out = jitted(state, batch)
                jax.block_until_ready(out)
            measured[strat] = (time.perf_counter() - t0) / 3
        print(f"  {strat:8s} {measured[strat]*1e3:10.1f} ms (measured)")

    pred_best = result.best
    meas_best = min(measured, key=measured.get)
    print(f"\npredicted best: {pred_best}; measured best: {meas_best}")


if __name__ == "__main__":
    main()
