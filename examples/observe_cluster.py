"""Observability end to end: replay the COMMITTED golden trace against a
live ``repro.cluster`` server subprocess, with full instrumentation on —
then show what the obs layer saw.

    PYTHONPATH=src python examples/observe_cluster.py

What runs:

  1. ``python -m repro.cluster`` is spawned with ``--metrics-port 0``: the
     server wires one ``Observability`` bundle through its frontend, pool,
     engine, and listener, and opens a Prometheus text endpoint.
  2. The golden fixture trace (``tests/fixtures/trace_golden_v1.jsonl`` —
     the same bytes the determinism test pins) is replayed over the wire.
     Every request carries a trace context, so the server's
     admit/queue/dispatch/engine/reply spans come back in each reply and
     the client reconstructs complete cross-process trees.
  3. Each served prediction is fed to a ``CalibrationMonitor`` against a
     simulated ground truth (the model's own answer + ~10% lognormal
     noise), so the per-device rolling MAPE gauges go live.

What prints: the span tree of the SLOWEST replayed request, the live MAPE
gauges, a scrape of the server registry over the predict socket
(``op="metrics"``), and a few raw Prometheus endpoint lines.
"""
import sys
import urllib.request
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.cluster import RemoteReplica  # noqa: E402
from repro.cluster.remote import spawn_demo_server  # noqa: E402
from repro.obs import (CalibrationMonitor, MetricsRegistry,  # noqa: E402
                       Observability, Tracer)
from repro.workloads.trace import TraceReplayer, load_trace  # noqa: E402

GOLDEN = Path(__file__).resolve().parents[1] / "tests" / "fixtures" \
    / "trace_golden_v1.jsonl"


class TracedTarget:
    """Predict-shaped replay target: one root span per request, context on
    the wire, (duration, trace_id) kept so we can find the slowest tree."""

    def __init__(self, replica, obs):
        self.replica = replica
        self.obs = obs
        self.requests = []          # (dur_s, trace_id, kernel-ish tag)

    def predict(self, x, *, deadline_s=None, priority=None):
        root = self.obs.tracer.start("replay.request")
        try:
            y = self.replica.predict(x, deadline_s=deadline_s,
                                     priority=priority, trace_ctx=root.ctx)
        finally:
            dur = self.obs.tracer.finish(root)
            self.requests.append((dur, root.trace_id))
        return y


def main():
    trace = load_trace(GOLDEN)
    print(f"== golden trace: {trace.name}, {len(trace.events)} events, "
          f"{trace.n_features} features ==")

    print("== spawn instrumented server (subprocess, --metrics-port 0) ==")
    proc, host, port, mhost, mport = spawn_demo_server(
        n_features=trace.n_features, metrics_port=0)
    print(f"   predictions on {host}:{port}, "
          f"prometheus on http://{mhost}:{mport}/metrics")

    # client-side bundle: a tracer big enough to retain every replayed
    # trace, and a calibration monitor the replay observer feeds
    registry = MetricsRegistry()
    obs = Observability(
        registry=registry,
        tracer=Tracer(max_traces=2 * len(trace.events),
                      slow_threshold_s=0.25),
        calibration=CalibrationMonitor(registry))
    rng = np.random.default_rng(0)

    def feed_calibration(ev, outcome):
        # no real hardware behind the demo server: simulate ground truth
        # as the prediction distorted by ~10% lognormal measurement noise
        measured = outcome.prediction * float(rng.lognormal(0.0, 0.1))
        obs.calibration.record("demo-device", "time_us",
                               predicted=outcome.prediction,
                               measured=measured, kernel=ev.kernel)

    try:
        replica = RemoteReplica(host, port, timeout_s=30.0, obs=obs)
        target = TracedTarget(replica, obs)
        print("== replay over the wire (every request traced) ==")
        report = TraceReplayer(target, pacing="open", speed=4.0,
                               obs=obs, observer=feed_calibration,
                               ).replay(trace)
        print(f"   served={report.count('served')} "
              f"shed={report.count('shed')} "
              f"expired={report.count('expired')} "
              f"p99={report.served_wall_ms(99):.1f}ms "
              f"digest={report.digest()[:16]}")

        print("\n== span tree of the SLOWEST request ==")
        dur, tid = max(target.requests)
        print(f"   {dur * 1e3:.2f}ms end to end "
              f"(ingested {obs.tracer.n_ingested} server spans total)")
        print(obs.tracer.render_tree(tid))

        print("\n== live calibration MAPE gauges (client registry) ==")
        for (device, tgt), (mape, n) in sorted(
                obs.calibration.series().items()):
            drifted = obs.calibration.drifted(25.0)
            print(f"   calibration.mape{{device={device},target={tgt}}} "
                  f"= {mape:.2f}% over {n} samples "
                  f"(drifted@25%: {drifted})")
        worst = sorted(obs.calibration.mape_by_kernel(
            "demo-device", "time_us").items(),
            key=lambda kv: -kv[1])[:3]
        for kernel, mape in worst:
            print(f"   worst kernels: {kernel} {mape:.1f}%")

        print("\n== server registry over the wire (op=\"metrics\") ==")
        body = replica.metrics()
        for row in body["metrics"]:
            if row["name"] in ("frontend.submitted", "frontend.served",
                               "engine.predictions", "engine.batches",
                               "server.requests_served"):
                print(f"   {row['name']} = {row['value']:.0f}")
        wait = next(r for r in body["metrics"]
                    if r["name"] == "frontend.wait_s")
        print(f"   frontend.wait_s p50={wait['p50'] * 1e3:.2f}ms "
              f"p99={wait['p99'] * 1e3:.2f}ms over {wait['count']} waits")

        print("\n== prometheus endpoint (first matching lines) ==")
        with urllib.request.urlopen(
                f"http://{mhost}:{mport}/metrics", timeout=10) as resp:
            text = resp.read().decode()
        hits = [line for line in text.splitlines()
                if line.startswith(("repro_frontend_served",
                                    "repro_engine_predictions",
                                    "repro_frontend_wait_s_p"))]
        for line in hits[:6]:
            print(f"   {line}")
        replica.close()
    finally:
        proc.kill()
        proc.wait(timeout=10)
    print("\nOK")


if __name__ == "__main__":
    main()
