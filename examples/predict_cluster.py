"""The paper's motivating use case (§1): schedule a mixed batch of kernels
across a HETEROGENEOUS cluster (five TPU device models) using per-device
trained forests — features recorded once, one forest per device type
(retraining = re-measuring targets only, the paper's portability property).

    PYTHONPATH=src python examples/predict_cluster.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main():
    from repro.core.devices import SIMULATED_DEVICES
    from repro.core.forest import ExtraTreesRegressor
    from repro.core.scheduler import (DevicePredictor, schedule,
                                      speedup_vs_baseline)
    from repro.workloads.collect import load_or_collect

    ds = load_or_collect(fast=True, progress=print).reduce_overrepresented()
    devs = []
    X_all = None
    for d in SIMULATED_DEVICES:
        X, y, _ = ds.matrix(d.name, "time_us")
        _, p, _ = ds.matrix(d.name, "power_w")
        t_model = ExtraTreesRegressor(n_estimators=48, seed=0).fit(
            X.astype(np.float32), np.log(y))
        p_model = ExtraTreesRegressor(n_estimators=48, seed=1).fit(
            X.astype(np.float32), p)
        devs.append(DevicePredictor(d.name, t_model.predict, p_model.predict,
                                    count=2))
        X_all = X.astype(np.float32)
        print(f"trained forests for {d.name} ({len(y)} samples)")

    out = speedup_vs_baseline(X_all, devs)
    print(f"\nmakespan: scheduled {out['scheduled_us']/1e3:.1f} ms | "
          f"round-robin {out['round_robin_us']/1e3:.1f} ms | "
          f"single-device {out['single_device_us']/1e3:.1f} ms")
    print(f"speedup vs round-robin: {out['speedup_vs_rr']:.2f}x; "
          f"vs single device: {out['speedup_vs_single']:.2f}x")
    print(f"scheduling cost: {out['predict_seconds']*1e3:.1f} ms for "
          f"{X_all.shape[0]} kernels x {len(devs)} device types "
          f"(paper §7.1 requires <= task granularity)")

    sched = schedule(X_all, devs, objective="energy")
    print(f"energy-objective schedule: {sched.energy_j:.2f} J predicted")


if __name__ == "__main__":
    main()
