"""The paper's motivating use case (§1): schedule a mixed batch of kernels
across a HETEROGENEOUS cluster (five TPU device models) using per-device
trained forests — features recorded once, one forest per device type
(retraining = re-measuring targets only, the paper's portability property).

Then the DVFS act: the idle/dynamic power split is FITTED from EDGE_DVFS
frequency sweeps, every device exposes its operating-point grid, and
``schedule(objective="energy", deadline_s=...)`` picks a frequency PER
KERNEL — the energy-vs-deadline Pareto sweep printed at the end shows
per-kernel selection meeting deadlines no fixed clock can, at less energy
than fixed-nominal.

    PYTHONPATH=src python examples/predict_cluster.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main():
    from repro.core.devices import SIMULATED_DEVICES
    from repro.core.forest import ExtraTreesRegressor
    from repro.core.scheduler import (DevicePredictor, schedule,
                                      speedup_vs_baseline)
    from repro.workloads.collect import load_or_collect

    ds = load_or_collect(fast=True, progress=print).reduce_overrepresented()
    devs = []
    X_all = None
    for d in SIMULATED_DEVICES:
        X, y, _ = ds.matrix(d.name, "time_us")
        _, p, _ = ds.matrix(d.name, "power_w")
        t_model = ExtraTreesRegressor(n_estimators=48, seed=0).fit(
            X.astype(np.float32), np.log(y))
        p_model = ExtraTreesRegressor(n_estimators=48, seed=1).fit(
            X.astype(np.float32), p)
        devs.append(DevicePredictor(d.name, t_model.predict, p_model.predict,
                                    count=2))
        X_all = X.astype(np.float32)
        print(f"trained forests for {d.name} ({len(y)} samples)")

    out = speedup_vs_baseline(X_all, devs)
    print(f"\nmakespan: scheduled {out['scheduled_us']/1e3:.1f} ms | "
          f"round-robin {out['round_robin_us']/1e3:.1f} ms | "
          f"single-device {out['single_device_us']/1e3:.1f} ms")
    print(f"speedup vs round-robin: {out['speedup_vs_rr']:.2f}x; "
          f"vs single device: {out['speedup_vs_single']:.2f}x")
    print(f"scheduling cost: {out['predict_seconds']*1e3:.1f} ms for "
          f"{X_all.shape[0]} kernels x {len(devs)} device types "
          f"(paper §7.1 requires <= task granularity)")

    sched = schedule(X_all, devs, objective="energy")
    print(f"energy-objective schedule: {sched.energy_j:.2f} J predicted")

    # ---- per-kernel DVFS under deadlines (the PR 5 subsystem) ----------
    from repro.core.devices import EDGE_DVFS, SIMULATED_DEVICES as DEVS
    from repro.core.power import (CUBIC_SPLIT, collect_dvfs_samples,
                                  fit_power_split, split_rmse)
    from repro.core.simulate import WorkloadSpec

    specs = [WorkloadSpec(flops=10.0**e, hbm_bytes=10.0**(e - 1),
                          collective_bytes=0.0, special_ops=10.0**(e - 3),
                          control_ops=0.0, work_items=10.0**(e - 6))
             for e in (9, 10, 11, 12)]
    freqs, ratios = collect_dvfs_samples(specs, EDGE_DVFS, seed=0)
    split, rmse = fit_power_split(freqs, ratios)
    print(f"\nfitted power split from EDGE_DVFS sweep: "
          f"idle={split.idle_frac:.2f} alpha={split.alpha:.2f} "
          f"(rmse {rmse:.4f} vs assumed-cubic "
          f"{split_rmse(CUBIC_SPLIT, freqs, ratios):.4f})")

    for d, dev in zip(devs, DEVS):
        d.freq_grid = dev.freq_grid
        d.power_split = split
    fastest = schedule(X_all, devs, objective="makespan")
    print("energy-vs-deadline Pareto (per-kernel frequency selection):")
    for mult in (1.05, 1.3, 2.0):
        deadline_s = fastest.makespan_us * mult / 1e6
        s = schedule(X_all, devs, objective="energy", deadline_s=deadline_s)
        mix = sorted({a.freq for a in s.assignments})
        print(f"  deadline {deadline_s * 1e3:7.2f} ms: "
              f"{s.energy_j:.3f} J, meets={s.meets_deadline}, "
              f"freq mix {mix}")


if __name__ == "__main__":
    main()
