"""Serve the trained predictor behind the ForestEngine: the deployment loop
the paper motivates (§7.1 — prediction latency must be orders of magnitude
below kernel execution time for schedulers to use the model).

 1. train per-device forests on the simulated-device dataset,
 2. stand up one engine per (device, target); the engine self-calibrates and
    picks the fastest inference path for this host,
 3. fire a burst of single-kernel async requests — they get micro-batched
    into a handful of forest calls,
 4. re-query the same kernels — pure cache hits (portability: a kernel's
    features, hence its prediction, never change per device),
 5. price a whole (kernels x devices) matrix in one call and schedule.

    PYTHONPATH=src python examples/serve_predictor.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def main():
    from repro.core.devices import SIMULATED_DEVICES
    from repro.core.forest import ExtraTreesRegressor
    from repro.core.scheduler import schedule
    from repro.serve import EngineConfig, ForestEngine, MultiDeviceEngine
    from repro.workloads.collect import load_or_collect

    ds = load_or_collect(fast=True, progress=lambda *_: None)
    ds = ds.reduce_overrepresented()

    print("== training per-device forests ==")
    fits = {}
    X = None
    for d in SIMULATED_DEVICES[:3]:
        Xd, y, _ = ds.matrix(d.name, "time_us")
        est = ExtraTreesRegressor(n_estimators=64, seed=0).fit(
            Xd.astype(np.float32), np.log(y))
        fits[d.name] = (est, None)
        X = Xd.astype(np.float32)
    print(f"   {len(fits)} devices, {X.shape[0]} kernels")

    print("== engine self-calibration ==")
    eng = ForestEngine(fits[SIMULATED_DEVICES[0].name][0],
                       EngineConfig(backend="auto", max_batch=32,
                                    max_delay_ms=2.0))
    for name, sec in sorted(eng.calibration.items(), key=lambda kv: kv[1]):
        mark = " <- selected" if name == eng.backend else ""
        print(f"   {name:12s} {sec * 1e3:7.2f} ms/flush-batch{mark}")

    print("== async burst (micro-batching) ==")
    n = min(200, X.shape[0])
    t0 = time.perf_counter()
    futs = [eng.predict_async(X[i]) for i in range(n)]
    preds = [f.result(timeout=30) for f in futs]
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"   {n} singles -> {s.batches} forest calls in {dt * 1e3:.1f} ms "
          f"({dt / n * 1e6:.0f} us/prediction)")

    print("== repeat queries (cache) ==")
    t0 = time.perf_counter()
    eng.predict(X[:n])
    dt = time.perf_counter() - t0
    print(f"   warm: {dt / n * 1e6:.2f} us/prediction, "
          f"hit_rate={s.hit_rate():.2f}, cache={eng.cache_len()} entries")
    eng.close()

    print("== multi-device pricing + schedule ==")
    mde = MultiDeviceEngine.from_fits(
        fits, counts={name: 2 for name in fits},
        config=EngineConfig(backend="auto"))
    t0 = time.perf_counter()
    T, P = mde.price(X)
    dt = time.perf_counter() - t0
    print(f"   priced {T.shape[0]}x{T.shape[1]} matrix in {dt * 1e3:.1f} ms")
    sched = schedule(X, mde)
    print(f"   makespan={sched.makespan_us:.0f} us "
          f"(predict={sched.predict_seconds * 1e3:.1f} ms, cached)")
    mde.close()
    print("done.")


if __name__ == "__main__":
    main()
