"""Diff a fresh ``BENCH_results.json`` against a committed baseline run.

    # regression gate (BLOCKING in CI):
    PYTHONPATH=src python -m benchmarks.diff_results BASELINE [FRESH]
        [--threshold 0.35] [--min-abs-us 20.0]

    # median-merge N runs of the same bench (the CI noise characterization):
    PYTHONPATH=src python -m benchmarks.diff_results \
        --merge-median OUT.json RUN1.json RUN2.json [RUN3.json ...]

Flags latency/throughput rows that regressed beyond their PER-METRIC
threshold (relative) AND ``min_abs_us`` (absolute — microsecond-scale rows
jitter on shared CI runners). Exit status 1 when any regression is flagged.
The ``bench-regression`` CI job is BLOCKING: it runs the fast-profile
latency bench 3x, takes the per-row median (``--merge-median``, which also
prints each row's observed spread — the noise characterization), and diffs
that median against the committed baseline.

Per-metric thresholds (``THRESHOLDS``) exist because noise is not uniform:
queueing rows (``latency.frontend.*``, ``latency.remote.*``) measure
wait-time distributions that swing with runner load, while pure-compute
rows (``latency.table45.*``) are comparatively stable. The values were
recorded from the 3x-run spread observed in the characterization step
(2025-07: median spread on hosted runners was <=15% for compute rows and
up to ~45% for queueing rows even AFTER taking the median of 3) with ~1.5x
margin on top, so the gate is quiet-by-default yet still catches a real
2x regression. Tighten here — in a committed, reviewed file — as runner
noise data accumulates, not ad hoc in CI.

Only rows where LOWER IS BETTER are compared: names under ``latency.`` and
the per-bench ``bench.*.wall`` rows. Rows tagged ``unit=percent`` in their
``derived`` field (hit rates, accuracy summaries) are skipped. Rows that
appear or disappear between runs are reported but never flagged — a new
benchmark must not fail its own introduction.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

#: (name prefix, relative regression threshold) — first match wins; rows
#: matching no prefix use the CLI ``--threshold`` base. See the module
#: docstring for how these were characterized.
THRESHOLDS = (
    ("latency.trace.codec", 0.50),  # pure-python codec, compute-steady
    ("latency.trace.gen", 0.50),    # seeded generators, compute-steady
    ("latency.trace.", 1.00),       # trace replay drives open-loop queueing
                                    # at and past the knee, like the
                                    # saturation family below
    ("latency.frontend.saturation", 1.00),  # open-loop queueing at/past the
                                    # knee: p99 is dominated by queue depth
                                    # vs offered-load phase, the noisiest
                                    # row family we gate (2x still flags)
    ("latency.frontend.", 0.70),    # queue-wait dominated: load-sensitive
    ("latency.remote.batch_v3", 1.00),      # a DELTA of two min-of-k walls
                                    # (wire overhead, single-digit us/row):
                                    # tiny absolute values, so relative
                                    # noise is large — the min_abs_us floor
                                    # does most of the gating here
    ("latency.obs.", 0.70),         # instrumented v3 batch total us/row:
                                    # same loopback-TCP queueing profile as
                                    # latency.remote.*; the overhead_pct in
                                    # the detail string is the signal, the
                                    # absolute total gates like remote rows
    ("latency.remote.pipelined", 1.00),     # 8-thread contention p99
    ("latency.remote.interop", 0.70),       # batched walls, v2-dominated
    ("latency.remote.", 0.70),      # loopback TCP + queueing on top
    ("latency.engine.async_burst", 0.70),   # micro-batch deadline timing
    ("latency.engine.", 0.50),      # batched engine rows
    ("latency.table45.", 0.50),     # pure compute, steadiest
    ("portability.graduation.", 1.00),      # one-shot forest fit + slot
                                    # swap wall inside a bench run: fit time
                                    # scales with the probe count at the
                                    # (data-dependent) plateau, so only a
                                    # 2x blowup flags
    ("bench.", 0.75),               # whole-bench wall time (imports, JIT)
)


def threshold_for(name: str, base: float) -> float:
    """Per-metric relative threshold: the first matching prefix, floored at
    the CLI base so a looser --threshold loosens everything."""
    for prefix, thr in THRESHOLDS:
        if name.startswith(prefix):
            return max(base, thr)
    return base


def load_rows(path: str | Path) -> dict[str, dict]:
    with open(path) as f:
        return json.load(f).get("rows", {})


def comparable(name: str, row: dict) -> bool:
    if "unit=percent" in row.get("derived", ""):
        return False
    return name.startswith("latency.") or (
        name.startswith(("bench.", "portability.graduation."))
        and name.endswith(".wall"))


def diff(baseline: dict[str, dict], fresh: dict[str, dict], *,
         threshold: float, min_abs_us: float) -> dict:
    regressions, improvements, added, removed = [], [], [], []
    for name, new in sorted(fresh.items()):
        if not comparable(name, new):
            continue
        old = baseline.get(name)
        if old is None or not comparable(name, old):
            added.append(name)
            continue
        a, b = float(old["us_per_call"]), float(new["us_per_call"])
        if a <= 0:
            continue
        rel = (b - a) / a
        thr = threshold_for(name, threshold)
        entry = {"name": name, "baseline_us": a, "fresh_us": b,
                 "rel": rel, "threshold": thr}
        if rel > thr and (b - a) > min_abs_us:
            regressions.append(entry)
        elif rel < -thr and (a - b) > min_abs_us:
            improvements.append(entry)
    for name, old in sorted(baseline.items()):
        if comparable(name, old) and name not in fresh:
            removed.append(name)
    return {"regressions": regressions, "improvements": improvements,
            "added": added, "removed": removed}


def merge_median(out_path: str, run_paths: list[str]) -> int:
    """Per-row median across N runs of the same bench + a printed noise
    characterization (relative spread across the runs, worst first).

    The median is what the regression gate diffs: one slow run out of three
    on a shared runner must not fail the build. The printed spread is the
    data the ``THRESHOLDS`` table is calibrated from.
    """
    runs = [load_rows(p) for p in run_paths]
    if len(runs) < 2:
        raise SystemExit("--merge-median needs at least 2 run files")
    merged: dict[str, dict] = {}
    noise: list[tuple[float, str, list[float]]] = []
    for name in sorted({n for rows in runs for n in rows}):
        rows = [r[name] for r in runs if name in r]
        values = [float(r["us_per_call"]) for r in rows]
        med = statistics.median(values)
        merged[name] = {**rows[0], "us_per_call": med}
        if comparable(name, rows[0]) and med > 0 and len(values) > 1:
            spread = (max(values) - min(values)) / med
            noise.append((spread, name, values))
    for spread, name, values in sorted(noise, reverse=True):
        lo, hi = min(values), max(values)
        print(f"NOISE {name}: spread {spread:.0%} over {len(values)} runs "
              f"({lo:.1f}..{hi:.1f}us, median "
              f"{merged[name]['us_per_call']:.1f}us)")
    with open(out_path, "w") as f:
        json.dump({"rows": merged,
                   "merged_from": len(runs),
                   "sources": list(run_paths)}, f, indent=1, sort_keys=True)
    print(f"# median of {len(runs)} runs ({len(merged)} rows) -> {out_path}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="diff mode: BASELINE [FRESH=BENCH_results.json]; "
                         "merge mode: RUN1 RUN2 [RUN3 ...]")
    ap.add_argument("--merge-median", metavar="OUT", default=None,
                    help="write the per-row median of the given runs to OUT "
                         "(prints the noise characterization) instead of "
                         "diffing")
    ap.add_argument("--threshold", type=float, default=0.35,
                    help="base relative regression flag level (default 0.35 "
                         "= 35%%); per-metric THRESHOLDS may raise it")
    ap.add_argument("--min-abs-us", type=float, default=20.0,
                    help="ignore deltas smaller than this many us")
    args = ap.parse_args(argv)

    if args.merge_median is not None:
        return merge_median(args.merge_median, args.paths)

    if not 1 <= len(args.paths) <= 2:
        ap.error("diff mode takes BASELINE [FRESH]")
    baseline = args.paths[0]
    fresh = args.paths[1] if len(args.paths) == 2 else "BENCH_results.json"
    report = diff(load_rows(baseline), load_rows(fresh),
                  threshold=args.threshold, min_abs_us=args.min_abs_us)
    for entry in report["improvements"]:
        print(f"IMPROVED   {entry['name']}: {entry['baseline_us']:.1f}us -> "
              f"{entry['fresh_us']:.1f}us ({entry['rel']:+.0%})")
    for name in report["added"]:
        print(f"NEW        {name}")
    for name in report["removed"]:
        print(f"REMOVED    {name}")
    for entry in report["regressions"]:
        print(f"REGRESSION {entry['name']}: {entry['baseline_us']:.1f}us -> "
              f"{entry['fresh_us']:.1f}us ({entry['rel']:+.0%}, "
              f"threshold {entry['threshold']:.0%})")
    n = len(report["regressions"])
    print(f"# {n} regression(s) above per-metric thresholds "
          f"(base {args.threshold:.0%}, +{args.min_abs_us:.0f}us floor)")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
