"""Diff a fresh ``BENCH_results.json`` against a committed baseline run.

    PYTHONPATH=src python -m benchmarks.diff_results BASELINE [FRESH]
        [--threshold 0.2] [--min-abs-us 5.0]

Flags latency/throughput rows that regressed by more than ``threshold``
(relative) AND ``min_abs_us`` (absolute — microsecond-scale rows jitter on
shared CI runners). Exit status 1 when any regression is flagged; the CI
job runs with ``continue-on-error`` so the flag is informational
(non-blocking), per the ROADMAP benchmarks item.

Only rows where LOWER IS BETTER are compared: names under ``latency.`` and
the per-bench ``bench.*.wall`` rows. Rows tagged ``unit=percent`` in their
``derived`` field (hit rates, accuracy summaries) are skipped. Rows that
appear or disappear between runs are reported but never flagged — a new
benchmark must not fail its own introduction.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load_rows(path: str | Path) -> dict[str, dict]:
    with open(path) as f:
        return json.load(f).get("rows", {})


def comparable(name: str, row: dict) -> bool:
    if "unit=percent" in row.get("derived", ""):
        return False
    return name.startswith("latency.") or (
        name.startswith("bench.") and name.endswith(".wall"))


def diff(baseline: dict[str, dict], fresh: dict[str, dict], *,
         threshold: float, min_abs_us: float) -> dict:
    regressions, improvements, added, removed = [], [], [], []
    for name, new in sorted(fresh.items()):
        if not comparable(name, new):
            continue
        old = baseline.get(name)
        if old is None or not comparable(name, old):
            added.append(name)
            continue
        a, b = float(old["us_per_call"]), float(new["us_per_call"])
        if a <= 0:
            continue
        rel = (b - a) / a
        entry = {"name": name, "baseline_us": a, "fresh_us": b,
                 "rel": rel}
        if rel > threshold and (b - a) > min_abs_us:
            regressions.append(entry)
        elif rel < -threshold and (a - b) > min_abs_us:
            improvements.append(entry)
    for name, old in sorted(baseline.items()):
        if comparable(name, old) and name not in fresh:
            removed.append(name)
    return {"regressions": regressions, "improvements": improvements,
            "added": added, "removed": removed}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_results.json")
    ap.add_argument("fresh", nargs="?", default="BENCH_results.json",
                    help="freshly produced results (default: ./BENCH_results.json)")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative regression flag level (default 0.2 = 20%%)")
    ap.add_argument("--min-abs-us", type=float, default=5.0,
                    help="ignore deltas smaller than this many us")
    args = ap.parse_args(argv)

    report = diff(load_rows(args.baseline), load_rows(args.fresh),
                  threshold=args.threshold, min_abs_us=args.min_abs_us)
    for entry in report["improvements"]:
        print(f"IMPROVED   {entry['name']}: {entry['baseline_us']:.1f}us -> "
              f"{entry['fresh_us']:.1f}us ({entry['rel']:+.0%})")
    for name in report["added"]:
        print(f"NEW        {name}")
    for name in report["removed"]:
        print(f"REMOVED    {name}")
    for entry in report["regressions"]:
        print(f"REGRESSION {entry['name']}: {entry['baseline_us']:.1f}us -> "
              f"{entry['fresh_us']:.1f}us ({entry['rel']:+.0%})")
    n = len(report["regressions"])
    print(f"# {n} regression(s) above {args.threshold:.0%} "
          f"(+{args.min_abs_us:.0f}us floor)")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
