"""Paper Fig. 8/9 + §8 summary: portability — per-device nested-CV MAPE for
time and power. Features are recorded ONCE; each device re-measures only
ground truth (the paper's central claim). The edge-dvfs device reproduces
the GTX 1650 finding: uncontrolled frequency => poor TIME predictability
(paper: 52 % median MAPE) while POWER stays ~2-3 % everywhere.

``portability.coldstart.*`` is the COLD-START learning curve
(``core.transfer``, docs/portability.md): a held-out device arrives with an
UNKNOWN spec sheet (generic prior), probes stream in by feature-space
coverage, and the hybrid analytical+forest-residual predictor's eval MAPE
is checkpointed against a static ``AnalyticalBaseline`` that KNOWS the
device's spec — the ``crossover`` row is how many probes the cold model
needs to beat the informed roofline.

``portability.graduation.*`` closes the lifecycle (ISSUE 10 tentpole,
``serve.supervise``): a supervised transfer tier streams the same probe
schedule through a ``DatasetStore``, the supervisor watches the live MAPE
gauge and auto-graduates the device to a full ``ForestEngine`` swapped
into its ``ReplicaPool`` slot. Rows record the eval MAPE at the plateau
that triggered graduation, the eval MAPE of the graduated forest, the
wall time of the graduating cycle (fit + swap, the only ``.wall`` row the
regression gate compares), the same lifecycle on the synthetic CLIFF
device (misspecified prior — graduation must beat the plateau outright),
and the two fleet probe-budget policies headed by the same budget."""
from __future__ import annotations

import numpy as np

from repro.core.cv import nested_cv
from repro.core.dataset import DatasetStore, Sample
from repro.core.devices import DEVICE_MODELS, SIMULATED_DEVICES
from repro.core.forest import ExtraTreesRegressor
from repro.core.metrics import mape
from repro.core.simulate import AnalyticalBaseline
from repro.core.transfer import (TransferConfig, TransferPredictor,
                                 select_probes, transfer_learning_curve)

from .common import StopWatch, cv_config, dataset, emit, save_json

COLDSTART_DEVICE = "edge-dvfs"
COLDSTART_BUDGET = 64
COLDSTART_CHECKPOINTS = (0, 1, 2, 4, 8, 16, 32, 64)

#: conservative transfer config for the graduation scenario: heavy
#: shrinkage trusts the spec-sheet prior longer, which is exactly the
#: regime where the tier plateaus and graduation pays (docs/portability.md)
GRADUATION_TCONFIG = dict(min_samples_leaf=4, shrinkage=32.0)
GRADUATION_MIN_SAMPLES = 48
GRADUATION_CHUNK = 8
POLICY_BUDGET = 32


def run_coldstart(ds) -> dict:
    """MAPE vs. probe-samples-seen for a held-out device (ISSUE 9 tentpole).

    The eval split is fixed and seeded; probes are ORDERED by
    ``select_probes`` (farthest-point coverage), so the curve is the
    deterministic cold-start trajectory for this dataset."""
    dev = COLDSTART_DEVICE
    X, y, _ = ds.matrix(dev, "time_us")
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    n_eval = max(40, len(y) // 3)
    ev, pool = perm[:n_eval], perm[n_eval:]
    Xev, yev, Xp, yp = X[ev], y[ev], X[pool], y[pool]

    am_mape = mape(yev, AnalyticalBaseline(DEVICE_MODELS[dev]).predict(Xev))
    budget = int(min(COLDSTART_BUDGET, len(pool)))
    order = select_probes(Xp, budget)
    checkpoints = [n for n in COLDSTART_CHECKPOINTS if n <= budget]

    cold = TransferPredictor(f"{dev}-unseen")       # spec UNKNOWN
    with StopWatch() as sw:
        curve = transfer_learning_curve(
            cold, Xp[order], yp[order], Xev, yev, checkpoints)
    for n, m in curve:
        emit(f"portability.coldstart.n{n:03d}", sw.seconds * 1e6,
             f"n={n};mape={m:.2f}%;static_am={am_mape:.2f}%;"
             f"device={dev};mode={'prior' if n == 0 else cold.mode}")

    crossover = next((n for n, m in curve if m < am_mape), None)
    emit("portability.coldstart.crossover", sw.seconds * 1e6,
         f"n_cross={crossover};budget={budget};static_am={am_mape:.2f}%")

    # skyline: a full per-device forest trained on the ENTIRE probe pool
    sky = ExtraTreesRegressor(n_estimators=48, seed=0)
    sky.fit(Xp.astype(np.float32),
            np.log(np.maximum(yp, 1e-9)).astype(np.float32))
    sky_mape = mape(yev, np.exp(sky.predict(Xev.astype(np.float32))))
    emit("portability.coldstart.skyline", 0.0,
         f"mape={sky_mape:.2f}%;n_train={len(yp)}")

    mapes = [m for _, m in curve]
    checks = {
        # each checkpoint no worse than the previous (10 % noise slack),
        # and the budgeted model is far below day zero
        "monotone_improvement":
            all(b <= a * 1.10 for a, b in zip(mapes, mapes[1:]))
            and mapes[-1] < 0.5 * mapes[0],
        "crosses_static_am_within_budget": crossover is not None,
        "final_within_1p5x_of_skyline": mapes[-1] <= 1.5 * sky_mape,
    }
    emit("portability.coldstart.claims", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"device": dev, "curve": curve, "static_am_mape": am_mape,
            "crossover_n": crossover, "skyline_mape": sky_mape,
            "budget": budget, "claims": checks}


def _probe_samples(X, y, device: str, idx, start: int = 0) -> list[Sample]:
    return [Sample(app="bench", kernel=f"k{start + k}", variant="g",
                   features=X[j], targets={device: {"time_us": float(y[j])}})
            for k, j in enumerate(idx)]


def _graduate_lifecycle(key: str, Xp, yp, Xev, yev, *,
                        min_samples: int) -> dict:
    """Stream ``select_probes``-ordered chunks through a supervised
    transfer tier until it auto-graduates; measure the lifecycle.

    Returns the eval MAPE at the plateau that triggered graduation, of
    the graduated forest serving from the slot, the graduating cycle's
    wall (fit + swap), and a gauge-continuity check (post-graduation
    feedback lands in the SAME ``calibration.mape`` series the transfer
    tier reported into). Deterministic: split, probe order, chunking and
    every fit are seeded, so reruns are exact."""
    from repro.cluster.replicas import ReplicaPool
    from repro.obs.calibration import CalibrationMonitor
    from repro.serve.engine import EngineConfig
    from repro.serve.supervise import SupervisorConfig, TransferSupervisor

    mon = CalibrationMonitor(alpha=0.3)
    tp = TransferPredictor(key, monitor=mon,
                           config=TransferConfig(**GRADUATION_TCONFIG))
    store = DatasetStore()
    pool = ReplicaPool({"cold": tp}, check_interval_s=60.0)
    sup = TransferSupervisor(
        store, mon, pool=pool,
        config=SupervisorConfig(
            min_graduate_samples=min_samples, plateau_window=3,
            engine_config=EngineConfig(backend="tree-walk", cache_size=0)))
    sup.manage(tp, replica="cold", key=key)

    order = select_probes(Xp, len(Xp))
    def serving():
        return pool.replicas["cold"].engine   # follows the graduation swap

    plateau_mape = mape(yev, serving().predict(Xev))          # day zero
    swap_wall_us, n_at, graduated_auto = 0.0, 0, False
    for start in range(0, len(order), GRADUATION_CHUNK):
        if sup.stats_snapshot()["devices"][key]["stage"] == "transfer":
            plateau_mape = mape(yev, serving().predict(Xev))
        store.extend(_probe_samples(Xp, yp, key,
                                    order[start:start + GRADUATION_CHUNK],
                                    start=start))
        with StopWatch() as sw:
            out = sup.supervise_once()
        if out["graduated"]:
            graduated_auto, n_at = True, tp.stats_snapshot().n_observed
            swap_wall_us = sw.seconds * 1e6   # the cycle that fit + swapped
            break
    if not graduated_auto:                    # never plateaued in-pool:
        with StopWatch() as sw:               # record the forced swap cost
            sup.graduate(key)
        n_at, swap_wall_us = tp.stats_snapshot().n_observed, sw.seconds * 1e6
    post_mape = mape(yev, serving().predict(Xev))

    # post-graduation feedback: later measurements keep scoring the forest
    # in the SAME calibration gauge the transfer tier reported into
    gauge_n_before = mon.series()[(key, "time_us")][1]
    tail = order[-GRADUATION_CHUNK:]
    store.extend(_probe_samples(Xp, yp, key, tail, start=1000))
    feedback = sup.supervise_once()["feedback"]
    gauge_continuity = (feedback == len(tail) and
                        mon.series()[(key, "time_us")][1]
                        == gauge_n_before + len(tail))
    snap = sup.stats_snapshot()
    pool.close()
    return {"plateau_mape": plateau_mape, "post_mape": post_mape,
            "n_at": n_at, "swap_wall_us": swap_wall_us,
            "graduated_auto": graduated_auto,
            "gauge_continuity": gauge_continuity, "snapshot": snap}


def run_graduation(ds) -> dict:
    """Auto-graduation lifecycle + probe-budget policies (ISSUE 10).

    Two lifecycle lanes: the REAL held-out device (honest
    characterization — a well-specified prior means the unshrunk forest
    lands near, not below, the hybrid plateau) and the synthetic CLIFF
    device (`serve.supervise.cliff_rows`: off-spec behavior the prior
    family cannot express — the regime graduation exists for, where the
    graduated forest must beat the plateau outright)."""
    from repro.core.devices import TPU_V5E
    from repro.obs.calibration import CalibrationMonitor
    from repro.serve.supervise import TransferSupervisor, cliff_rows

    dev = COLDSTART_DEVICE
    X, y, _ = ds.matrix(dev, "time_us")
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    n_eval = max(40, len(y) // 3)
    ev, pool_idx = perm[:n_eval], perm[n_eval:]
    Xev, yev, Xp, yp = X[ev], y[ev], X[pool_idx], y[pool_idx]
    key = f"{dev}-unseen"

    # ---- lane 1: the real held-out device
    real = _graduate_lifecycle(key, Xp, yp, Xev, yev,
                               min_samples=GRADUATION_MIN_SAMPLES)
    snap = real["snapshot"]
    emit("portability.graduation.plateau", 0.0,
         f"mape={real['plateau_mape']:.2f}%;unit=percent;device={dev};"
         f"n_at_graduation={real['n_at']}")
    emit("portability.graduation.post", 0.0,
         f"mape={real['post_mape']:.2f}%;unit=percent;device={dev};"
         f"slot_generation={snap['devices'][key]['slot_generation']}")
    emit("portability.graduation.swap.wall", real["swap_wall_us"],
         f"n_fit={real['n_at']};auto={real['graduated_auto']};"
         f"graduations={snap['stats'].graduations}")

    # ---- lane 2: the cliff device (misspecified-prior regime)
    Xc, yc = cliff_rows(TPU_V5E, 160, seed=1)
    Xcev, ycev = cliff_rows(TPU_V5E, 48, seed=2)
    cliff = _graduate_lifecycle("cliff-accelerator", Xc, yc, Xcev, ycev,
                                min_samples=96)
    emit("portability.graduation.cliff", 0.0,
         f"plateau_mape={cliff['plateau_mape']:.2f}%;"
         f"post_mape={cliff['post_mape']:.2f}%;unit=percent;"
         f"n_at_graduation={cliff['n_at']};auto={cliff['graduated_auto']}")

    # ---- fleet probe budgeting: same budget, both policies, measured
    order = select_probes(Xp, len(Xp))
    policy_mapes = {}
    for policy in ("highest-mape", "coverage"):
        mon2 = CalibrationMonitor(alpha=0.3, min_samples=2)
        sup2 = TransferSupervisor(DatasetStore(), mon2)
        tps = {}
        for name, warm in (("fleet-a", 12), ("fleet-b", 4)):
            tps[name] = TransferPredictor(
                name, monitor=mon2, config=TransferConfig(**GRADUATION_TCONFIG))
            sup2.manage(tps[name], key=name)
            for j in order[:warm]:            # uneven head start -> gauges
                tps[name].observe(Xp[j], float(yp[j]))
        with StopWatch() as sw:
            plan = sup2.plan_probes(Xp, POLICY_BUDGET, policy=policy)
        for name, row in plan:                # execute the plan
            tps[name].observe(Xp[row], float(yp[row]))
        fleet = {name: mape(yev, t.predict(Xev)) for name, t in tps.items()}
        policy_mapes[policy] = max(fleet.values())
        counts = {name: sum(1 for n, _ in plan if n == name) for name in tps}
        emit(f"portability.graduation.policy.{policy}", 0.0,
             f"worst_mape={policy_mapes[policy]:.2f}%;unit=percent;"
             + ";".join(f"{n}_mape={m:.2f}" for n, m in sorted(fleet.items()))
             + ";" + ";".join(f"{n}_probes={c}"
                              for n, c in sorted(counts.items()))
             + f";plan_us={sw.seconds * 1e6:.0f}")

    checks = {
        "graduated": snap["devices"][key]["stage"] == "forest",
        "slot_swapped_once": snap["devices"][key]["slot_generation"] == 1,
        # graduation must not give back what the transfer tier earned.
        # On real data with a WELL-specified prior the shrinkage floor is
        # not binding, so the unshrunk forest lands near (not below) the
        # hybrid plateau — same 1.5x convention as the coldstart skyline
        # claim. The strict post <= plateau bar belongs to the cliff lane.
        "post_within_1p5x_of_plateau":
            real["post_mape"] <= 1.5 * real["plateau_mape"],
        "post_beats_day_zero": real["post_mape"] < mape(
            yev, TransferPredictor(key).predict(Xev)),
        "gauge_continuity": real["gauge_continuity"],
        # the misspecified-prior regime: the graduated forest must beat
        # the shrinkage-floored plateau OUTRIGHT (same scenario the CI
        # smoke asserts; seeded, so this is exact, not probabilistic)
        "cliff_graduated_automatically": cliff["graduated_auto"],
        "cliff_post_below_plateau":
            cliff["post_mape"] <= cliff["plateau_mape"],
        # budget-constrained calibration: both policies leave every fleet
        # device below its day-zero prior
        "policies_beat_day_zero": max(policy_mapes.values()) < mape(
            yev, TransferPredictor("fleet-a").predict(Xev)),
    }
    emit("portability.graduation.claims", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"device": dev, "real": {k: v for k, v in real.items()
                                    if k != "snapshot"},
            "cliff": {k: v for k, v in cliff.items() if k != "snapshot"},
            "policy_worst_mape": policy_mapes, "claims": checks}


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    devices = [d.name for d in SIMULATED_DEVICES] + ["cpu-host"]
    out = {"time": {}, "power": {}}
    for dev in devices:
        for target, time_split in (("time_us", True), ("power_w", False)):
            X, y, _ = ds.matrix(dev, target)
            if not len(y):
                continue
            with StopWatch() as sw:
                res = nested_cv(X, y, cv_config(time_split))
            s = res.summary()
            kind = "time" if target == "time_us" else "power"
            out[kind][dev] = s
            emit(f"portability.fig8.{kind}.{dev}", sw.seconds * 1e6,
                 f"median_mape={s['median_mape']:.2f}%;"
                 f"q1={s['q1']:.2f};q3={s['q3']:.2f}")

    # the paper's qualitative claims, checked programmatically
    t = out["time"]
    p = out["power"]
    server = [d.name for d in SIMULATED_DEVICES if d.clazz == "server"]
    checks = {
        "server_time_mape_reasonable":
            all(t[d]["median_mape"] < 40 for d in server if d in t),
        # paper: GTX1650 52 % vs 8.9-13.9 % (~4x). Our server models sit
        # higher (the dataset includes the heterogeneous framework cells and
        # the fast CV profile uses small forests), so the separation factor
        # is ~1.7-2x; the check asserts the direction at 1.5x.
        "dvfs_time_much_worse":
            t["edge-dvfs"]["median_mape"] >
            1.5 * max(t[d]["median_mape"] for d in server if d in t),
        "power_easy_everywhere":
            all(v["median_mape"] < 8 for v in p.values()),
    }
    out["claims"] = checks
    emit("portability.claims", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    out["coldstart"] = run_coldstart(ds)
    out["graduation"] = run_graduation(ds)
    save_json("portability", out)
    return out


if __name__ == "__main__":
    run()
