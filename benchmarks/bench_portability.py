"""Paper Fig. 8/9 + §8 summary: portability — per-device nested-CV MAPE for
time and power. Features are recorded ONCE; each device re-measures only
ground truth (the paper's central claim). The edge-dvfs device reproduces
the GTX 1650 finding: uncontrolled frequency => poor TIME predictability
(paper: 52 % median MAPE) while POWER stays ~2-3 % everywhere.

``portability.coldstart.*`` is the COLD-START learning curve
(``core.transfer``, docs/portability.md): a held-out device arrives with an
UNKNOWN spec sheet (generic prior), probes stream in by feature-space
coverage, and the hybrid analytical+forest-residual predictor's eval MAPE
is checkpointed against a static ``AnalyticalBaseline`` that KNOWS the
device's spec — the ``crossover`` row is how many probes the cold model
needs to beat the informed roofline."""
from __future__ import annotations

import numpy as np

from repro.core.cv import nested_cv
from repro.core.devices import DEVICE_MODELS, SIMULATED_DEVICES
from repro.core.forest import ExtraTreesRegressor
from repro.core.metrics import mape
from repro.core.simulate import AnalyticalBaseline
from repro.core.transfer import (TransferPredictor, select_probes,
                                 transfer_learning_curve)

from .common import StopWatch, cv_config, dataset, emit, save_json

COLDSTART_DEVICE = "edge-dvfs"
COLDSTART_BUDGET = 64
COLDSTART_CHECKPOINTS = (0, 1, 2, 4, 8, 16, 32, 64)


def run_coldstart(ds) -> dict:
    """MAPE vs. probe-samples-seen for a held-out device (ISSUE 9 tentpole).

    The eval split is fixed and seeded; probes are ORDERED by
    ``select_probes`` (farthest-point coverage), so the curve is the
    deterministic cold-start trajectory for this dataset."""
    dev = COLDSTART_DEVICE
    X, y, _ = ds.matrix(dev, "time_us")
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    n_eval = max(40, len(y) // 3)
    ev, pool = perm[:n_eval], perm[n_eval:]
    Xev, yev, Xp, yp = X[ev], y[ev], X[pool], y[pool]

    am_mape = mape(yev, AnalyticalBaseline(DEVICE_MODELS[dev]).predict(Xev))
    budget = int(min(COLDSTART_BUDGET, len(pool)))
    order = select_probes(Xp, budget)
    checkpoints = [n for n in COLDSTART_CHECKPOINTS if n <= budget]

    cold = TransferPredictor(f"{dev}-unseen")       # spec UNKNOWN
    with StopWatch() as sw:
        curve = transfer_learning_curve(
            cold, Xp[order], yp[order], Xev, yev, checkpoints)
    for n, m in curve:
        emit(f"portability.coldstart.n{n:03d}", sw.seconds * 1e6,
             f"n={n};mape={m:.2f}%;static_am={am_mape:.2f}%;"
             f"device={dev};mode={'prior' if n == 0 else cold.mode}")

    crossover = next((n for n, m in curve if m < am_mape), None)
    emit("portability.coldstart.crossover", sw.seconds * 1e6,
         f"n_cross={crossover};budget={budget};static_am={am_mape:.2f}%")

    # skyline: a full per-device forest trained on the ENTIRE probe pool
    sky = ExtraTreesRegressor(n_estimators=48, seed=0)
    sky.fit(Xp.astype(np.float32),
            np.log(np.maximum(yp, 1e-9)).astype(np.float32))
    sky_mape = mape(yev, np.exp(sky.predict(Xev.astype(np.float32))))
    emit("portability.coldstart.skyline", 0.0,
         f"mape={sky_mape:.2f}%;n_train={len(yp)}")

    mapes = [m for _, m in curve]
    checks = {
        # each checkpoint no worse than the previous (10 % noise slack),
        # and the budgeted model is far below day zero
        "monotone_improvement":
            all(b <= a * 1.10 for a, b in zip(mapes, mapes[1:]))
            and mapes[-1] < 0.5 * mapes[0],
        "crosses_static_am_within_budget": crossover is not None,
        "final_within_1p5x_of_skyline": mapes[-1] <= 1.5 * sky_mape,
    }
    emit("portability.coldstart.claims", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    return {"device": dev, "curve": curve, "static_am_mape": am_mape,
            "crossover_n": crossover, "skyline_mape": sky_mape,
            "budget": budget, "claims": checks}


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    devices = [d.name for d in SIMULATED_DEVICES] + ["cpu-host"]
    out = {"time": {}, "power": {}}
    for dev in devices:
        for target, time_split in (("time_us", True), ("power_w", False)):
            X, y, _ = ds.matrix(dev, target)
            if not len(y):
                continue
            with StopWatch() as sw:
                res = nested_cv(X, y, cv_config(time_split))
            s = res.summary()
            kind = "time" if target == "time_us" else "power"
            out[kind][dev] = s
            emit(f"portability.fig8.{kind}.{dev}", sw.seconds * 1e6,
                 f"median_mape={s['median_mape']:.2f}%;"
                 f"q1={s['q1']:.2f};q3={s['q3']:.2f}")

    # the paper's qualitative claims, checked programmatically
    t = out["time"]
    p = out["power"]
    server = [d.name for d in SIMULATED_DEVICES if d.clazz == "server"]
    checks = {
        "server_time_mape_reasonable":
            all(t[d]["median_mape"] < 40 for d in server if d in t),
        # paper: GTX1650 52 % vs 8.9-13.9 % (~4x). Our server models sit
        # higher (the dataset includes the heterogeneous framework cells and
        # the fast CV profile uses small forests), so the separation factor
        # is ~1.7-2x; the check asserts the direction at 1.5x.
        "dvfs_time_much_worse":
            t["edge-dvfs"]["median_mape"] >
            1.5 * max(t[d]["median_mape"] for d in server if d in t),
        "power_easy_everywhere":
            all(v["median_mape"] < 8 for v in p.values()),
    }
    out["claims"] = checks
    emit("portability.claims", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    out["coldstart"] = run_coldstart(ds)
    save_json("portability", out)
    return out


if __name__ == "__main__":
    run()
