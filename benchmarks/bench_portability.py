"""Paper Fig. 8/9 + §8 summary: portability — per-device nested-CV MAPE for
time and power. Features are recorded ONCE; each device re-measures only
ground truth (the paper's central claim). The edge-dvfs device reproduces
the GTX 1650 finding: uncontrolled frequency => poor TIME predictability
(paper: 52 % median MAPE) while POWER stays ~2-3 % everywhere."""
from __future__ import annotations


from repro.core.cv import nested_cv
from repro.core.devices import SIMULATED_DEVICES

from .common import StopWatch, cv_config, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    devices = [d.name for d in SIMULATED_DEVICES] + ["cpu-host"]
    out = {"time": {}, "power": {}}
    for dev in devices:
        for target, time_split in (("time_us", True), ("power_w", False)):
            X, y, _ = ds.matrix(dev, target)
            if not len(y):
                continue
            with StopWatch() as sw:
                res = nested_cv(X, y, cv_config(time_split))
            s = res.summary()
            kind = "time" if target == "time_us" else "power"
            out[kind][dev] = s
            emit(f"portability.fig8.{kind}.{dev}", sw.seconds * 1e6,
                 f"median_mape={s['median_mape']:.2f}%;"
                 f"q1={s['q1']:.2f};q3={s['q3']:.2f}")

    # the paper's qualitative claims, checked programmatically
    t = out["time"]
    p = out["power"]
    server = [d.name for d in SIMULATED_DEVICES if d.clazz == "server"]
    checks = {
        "server_time_mape_reasonable":
            all(t[d]["median_mape"] < 40 for d in server if d in t),
        # paper: GTX1650 52 % vs 8.9-13.9 % (~4x). Our server models sit
        # higher (the dataset includes the heterogeneous framework cells and
        # the fast CV profile uses small forests), so the separation factor
        # is ~1.7-2x; the check asserts the direction at 1.5x.
        "dvfs_time_much_worse":
            t["edge-dvfs"]["median_mape"] >
            1.5 * max(t[d]["median_mape"] for d in server if d in t),
        "power_easy_everywhere":
            all(v["median_mape"] < 8 for v in p.values()),
    }
    out["claims"] = checks
    emit("portability.claims", 0.0,
         ";".join(f"{k}={v}" for k, v in checks.items()))
    save_json("portability", out)
    return out


if __name__ == "__main__":
    run()
