"""Paper §7.2 (PPT-GPU comparison) + Table 1 baselines: the learned RF vs
a static analytical roofline model (AM) and linear regression (LR/MLR) on
identical features — reproducing the finding that the learned model
dominates static analytics on heterogeneous workloads (the paper measured
PPT-GPU at 433.88 % MAPE vs its RF at ~9-14 %)."""
from __future__ import annotations

import numpy as np

from repro.core.devices import TPU_V5E
from repro.core.forest import ExtraTreesRegressor, LinearBaseline
from repro.core.metrics import mape
from repro.core.simulate import AnalyticalBaseline
from repro.core.split import time_stratified_kfold

from .common import StopWatch, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    X, y, _ = ds.matrix("tpu-v5e", "time_us")
    rng = np.random.default_rng(0)
    folds = time_stratified_kfold(y, 4, rng)
    scores = {"rf": [], "linear": [], "analytical": []}
    with StopWatch() as sw:
        for f in folds:
            rf = ExtraTreesRegressor(n_estimators=64, seed=0).fit(
                X[f.train].astype(np.float32), np.log(y[f.train]))
            scores["rf"].append(
                mape(y[f.test], np.exp(rf.predict(X[f.test].astype(np.float32)))))
            lb = LinearBaseline().fit(X[f.train], np.log(y[f.train]))
            scores["linear"].append(
                mape(y[f.test], np.exp(lb.predict(X[f.test]))))
            am = AnalyticalBaseline(TPU_V5E)
            scores["analytical"].append(mape(y[f.test], am.predict(X[f.test])))
    out = {k: {"mean_mape": float(np.mean(v)),
               "median_mape": float(np.median(v))} for k, v in scores.items()}
    out["rf_beats_am"] = out["rf"]["median_mape"] < out["analytical"]["median_mape"]
    for k, v in out.items():
        if isinstance(v, dict):
            emit(f"baseline.{k}", sw.seconds * 1e6 / 3,
                 f"median_mape={v['median_mape']:.2f}%")
    save_json("analytical_baseline", out)
    return out


if __name__ == "__main__":
    run()
