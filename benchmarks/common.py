"""Shared benchmark plumbing: dataset cache, CSV emitters, profiles.

Profiles trade fidelity for wall-time on this 1-core container:
  fast  — reduced CV (2 iterations, smaller tree grid); default
  paper — the paper's full grid {128,256,512,1024} trees, 3 iterations
Set REPRO_BENCH_PROFILE=paper to switch.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path


from repro.core.cv import CVConfig

ART = Path(__file__).resolve().parents[1] / "artifacts"
ART.mkdir(exist_ok=True)

PROFILE = os.environ.get("REPRO_BENCH_PROFILE", "fast")


def cv_config(time_split: bool) -> CVConfig:
    if PROFILE == "paper":
        return CVConfig(grid={"criterion": ["mse", "mae"],
                              "max_features": ["max", "log2", "sqrt"],
                              "n_estimators": [128, 256, 512, 1024]},
                        outer_folds=5, inner_folds=3, iterations=3,
                        time_split=time_split)
    return CVConfig(grid={"criterion": ["mse", "mae"],
                          "max_features": ["max", "log2", "sqrt"],
                          "n_estimators": [16, 32]},
                    outer_folds=3, inner_folds=2, iterations=2,
                    time_split=time_split)


# every emit() row of the current process, in order — benchmarks/run.py
# consolidates these into BENCH_results.json at the repo root so the perf
# trajectory is machine-readable across PRs
RESULTS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Required output contract: ``name,us_per_call,derived`` CSV rows."""
    RESULTS.append({"name": name, "us_per_call": float(us_per_call),
                    "derived": derived})
    print(f"{name},{us_per_call:.2f},{derived}")


def dataset(fast: bool | None = None):
    from repro.workloads.collect import load_or_collect
    if fast is None:
        fast = PROFILE == "fast"
    return load_or_collect(fast=fast, progress=lambda *_: None)


def save_json(name: str, obj) -> Path:
    path = ART / f"bench_{name}.json"
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


class StopWatch:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
