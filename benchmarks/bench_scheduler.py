"""The paper's §1 use case, quantified: predictor-driven heterogeneous
scheduling vs round-robin and single-device baselines, across the five
simulated device models; objective variants time / energy. Predictions are
served through the MultiDeviceEngine frontend — one ForestEngine per
(device, target), pricing the whole (kernels x devices x frequencies)
tensor in one batched call per engine, with repeat schedules hitting the
feature cache.

DVFS rows: the idle/dynamic power split is FITTED from EDGE_DVFS
frequency-sweep samples (``core.power.fit_power_split`` — beating the
assumed-cubic law, per Wang & Chu arXiv:1701.05308), every device exposes
its discrete ``freq_grid``, and the energy-vs-deadline PARETO sweep
compares per-kernel frequency selection (``schedule(deadline_s=...,
objective="energy")`` choosing f per assignment) against every
fixed-frequency baseline: at each deadline the row reports per-kernel
energy next to the best FEASIBLE fixed point's — the win the ROADMAP's
"per-kernel frequency selection" item asked for."""
from __future__ import annotations

import numpy as np

from repro.core.devices import EDGE_DVFS, EDGE_FREQ_GRID, SIMULATED_DEVICES
from repro.core.forest import ExtraTreesRegressor
from repro.core.power import fit_power_split, collect_dvfs_samples
from repro.core.scheduler import schedule, speedup_vs_baseline
from repro.core.simulate import WorkloadSpec
from repro.serve import EngineConfig, MultiDeviceEngine

from .common import dataset, emit, save_json


def _fitted_split():
    """Fit the idle/dynamic split from an EDGE_DVFS frequency sweep over a
    spread of workload intensities (the 'EDGE_DVFS samples')."""
    specs = [WorkloadSpec(flops=10.0**e, hbm_bytes=10.0**(e - 1),
                          collective_bytes=0.0, special_ops=10.0**(e - 3),
                          control_ops=0.0, work_items=10.0**(e - 6))
             for e in (9, 10, 11, 12)]
    freqs, ratios = collect_dvfs_samples(specs, EDGE_DVFS, seed=0)
    split, rmse = fit_power_split(freqs, ratios)
    from repro.core.power import CUBIC_SPLIT, split_rmse
    return split, rmse, split_rmse(CUBIC_SPLIT, freqs, ratios)


def _pin_grids(f: float) -> dict[str, tuple]:
    """Fixed-frequency baseline: pin every device to the largest point of
    ITS grid that does not exceed the global setting ``f``."""
    out = {}
    for d in SIMULATED_DEVICES:
        at_or_below = [g for g in d.freq_grid if g <= f + 1e-9]
        out[d.name] = (max(at_or_below) if at_or_below
                       else min(d.freq_grid),)
    return out


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    fits = {}
    X_all = None
    for d in SIMULATED_DEVICES:
        X, y, _ = ds.matrix(d.name, "time_us")
        _, p, _ = ds.matrix(d.name, "power_w")
        est_t = ExtraTreesRegressor(n_estimators=32, seed=0).fit(
            X.astype(np.float32), np.log(y))
        est_p = ExtraTreesRegressor(n_estimators=32, seed=1).fit(
            X.astype(np.float32), p)
        fits[d.name] = (est_t, est_p)
        X_all = X
    split, fit_rmse, cubic_rmse = _fitted_split()
    grids = {d.name: d.freq_grid for d in SIMULATED_DEVICES}
    splits = {d.name: split for d in SIMULATED_DEVICES}
    mde = MultiDeviceEngine.from_fits(
        fits, log_time=True, counts={d.name: 2 for d in SIMULATED_DEVICES},
        freq_grids=grids, power_splits=splits,
        config=EngineConfig(backend="auto"))
    X_all = X_all.astype(np.float32)
    try:
        cmp = speedup_vs_baseline(X_all, mde)
        sched_e = schedule(X_all, mde, objective="energy")
        sched_hot = schedule(X_all, mde)           # all predictions cached
        hit = np.mean([per["time_us"].stats.hit_rate()
                       for per in mde.engines.values()])

        # ---- energy-vs-deadline Pareto: per-kernel selection vs every
        # fixed-frequency baseline. Deadlines sweep outward from the
        # fastest (all-max-frequency) makespan; at each one the per-kernel
        # schedule must meet the deadline at no more energy than the best
        # fixed point that meets it.
        fastest = schedule(X_all, mde, objective="makespan")
        ms_fast_s = fastest.makespan_us / 1e6
        pareto = []
        wins = 0
        for mult in (1.05, 1.3, 2.0, 4.0):
            deadline_s = ms_fast_s * mult
            per_kernel = schedule(X_all, mde, objective="energy",
                                  deadline_s=deadline_s)
            fixed = {}
            for f in EDGE_FREQ_GRID:
                mde.freq_grids = _pin_grids(f)
                fixed[f] = schedule(X_all, mde, objective="energy",
                                    deadline_s=deadline_s)
            mde.freq_grids = grids
            feasible = {f: s for f, s in fixed.items() if s.meets_deadline}
            best_f, best_fixed = (min(feasible.items(),
                                      key=lambda kv: kv[1].energy_j)
                                  if feasible else (None, None))
            beats = (per_kernel.meets_deadline
                     and best_fixed is not None
                     and per_kernel.energy_j <= best_fixed.energy_j + 1e-12)
            wins += bool(beats and best_fixed is not None
                         and per_kernel.energy_j < best_fixed.energy_j)
            row = {"deadline_s": deadline_s,
                   "per_kernel_energy_j": per_kernel.energy_j,
                   "per_kernel_makespan_us": per_kernel.makespan_us,
                   "meets_deadline": per_kernel.meets_deadline,
                   "freq_mix": sorted({a.freq
                                       for a in per_kernel.assignments}),
                   "best_fixed_f": best_f,
                   "best_fixed_energy_j": (best_fixed.energy_j
                                           if best_fixed else None),
                   "beats_best_fixed": bool(beats)}
            pareto.append(row)
            tag = f"{mult:.2f}".replace(".", "p")
            emit(f"scheduler.pareto_d{tag}",
                 per_kernel.predict_seconds * 1e6,
                 f"energy={per_kernel.energy_j:.3f}J;"
                 f"fixed_best={0.0 if best_fixed is None else best_fixed.energy_j:.3f}J"
                 f"@f={best_f};meets={per_kernel.meets_deadline};"
                 f"beats_fixed={bool(beats)}")

        out = {"makespan": cmp, "energy_objective_j": sched_e.energy_j,
               "engine_backends": {n: per["time_us"].backend
                                   for n, per in mde.engines.items()},
               "hot_predict_seconds": sched_hot.predict_seconds,
               "cache_hit_rate": float(hit),
               "power_split": {"idle_frac": split.idle_frac,
                               "alpha": split.alpha,
                               "fit_rmse": fit_rmse,
                               "cubic_rmse": cubic_rmse},
               "pareto": pareto,
               "pareto_wins": wins}
        emit("scheduler.makespan", cmp["predict_seconds"] * 1e6,
             f"speedup_vs_rr={cmp['speedup_vs_rr']:.2f}x;"
             f"speedup_vs_single={cmp['speedup_vs_single']:.2f}x")
        emit("scheduler.energy", sched_e.predict_seconds * 1e6,
             f"energy={sched_e.energy_j:.3f}J")
        emit("scheduler.power_split", fit_rmse * 100,
             f"idle_frac={split.idle_frac:.3f};alpha={split.alpha:.2f};"
             f"cubic_rmse={cubic_rmse:.4f};fitted_rmse={fit_rmse:.4f};"
             f"unit=percent")
        emit("scheduler.energy_dvfs", sched_e.predict_seconds * 1e6,
             f"per_kernel_energy@tightest_deadline="
             f"{pareto[0]['per_kernel_energy_j']:.3f}J;"
             f"pareto_wins={wins}/4")
        emit("scheduler.hot_cache", sched_hot.predict_seconds * 1e6,
             f"hit_rate={hit:.2f}")
        save_json("scheduler", out)
        return out
    finally:
        mde.close()


if __name__ == "__main__":
    run()
