"""The paper's §1 use case, quantified: predictor-driven heterogeneous
scheduling vs round-robin and single-device baselines, across the five
simulated device models; objective variants time / energy. Predictions are
served through the MultiDeviceEngine frontend — one ForestEngine per
(device, target), pricing the whole (kernels x devices) matrix in one
batched call per engine, with repeat schedules hitting the feature cache.

Also exercises the DVFS groundwork: the edge-dvfs device is repriced at a
reduced frequency-scale (t /= f, P *= f^3 — DevicePredictor.freq_scale) and
the energy objective re-optimized at that operating point."""
from __future__ import annotations

import numpy as np

from repro.core.devices import SIMULATED_DEVICES
from repro.core.forest import ExtraTreesRegressor
from repro.core.scheduler import schedule, speedup_vs_baseline
from repro.serve import EngineConfig, MultiDeviceEngine

from .common import StopWatch, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    fits = {}
    X_all = None
    for d in SIMULATED_DEVICES:
        X, y, _ = ds.matrix(d.name, "time_us")
        _, p, _ = ds.matrix(d.name, "power_w")
        est_t = ExtraTreesRegressor(n_estimators=32, seed=0).fit(
            X.astype(np.float32), np.log(y))
        est_p = ExtraTreesRegressor(n_estimators=32, seed=1).fit(
            X.astype(np.float32), p)
        fits[d.name] = (est_t, est_p)
        X_all = X
    mde = MultiDeviceEngine.from_fits(
        fits, log_time=True, counts={d.name: 2 for d in SIMULATED_DEVICES},
        config=EngineConfig(backend="auto"))
    X_all = X_all.astype(np.float32)
    try:
        with StopWatch() as sw:
            cmp = speedup_vs_baseline(X_all, mde)
        sched_e = schedule(X_all, mde, objective="energy")
        sched_hot = schedule(X_all, mde)           # all predictions cached
        hit = np.mean([per["time_us"].stats.hit_rate()
                       for per in mde.engines.values()])

        # DVFS repricing: run edge-dvfs at 70% clock and re-optimize energy.
        # Predictions are all cached — only the pricing transform changes.
        mde.freq_scales["edge-dvfs"] = 0.7
        sched_dvfs = schedule(X_all, mde, objective="energy")
        mde.freq_scales["edge-dvfs"] = 1.0

        out = {"makespan": cmp, "energy_objective_j": sched_e.energy_j,
               "engine_backends": {n: per["time_us"].backend
                                   for n, per in mde.engines.items()},
               "hot_predict_seconds": sched_hot.predict_seconds,
               "cache_hit_rate": float(hit),
               "dvfs_energy_j_at_0p7": sched_dvfs.energy_j,
               "dvfs_makespan_us_at_0p7": sched_dvfs.makespan_us}
        emit("scheduler.makespan", cmp["predict_seconds"] * 1e6,
             f"speedup_vs_rr={cmp['speedup_vs_rr']:.2f}x;"
             f"speedup_vs_single={cmp['speedup_vs_single']:.2f}x")
        emit("scheduler.energy", sched_e.predict_seconds * 1e6,
             f"energy={sched_e.energy_j:.3f}J")
        emit("scheduler.energy_dvfs", sched_dvfs.predict_seconds * 1e6,
             f"energy={sched_dvfs.energy_j:.3f}J@f=0.7;"
             f"vs_nominal={sched_dvfs.energy_j / max(sched_e.energy_j, 1e-12):.3f}x")
        emit("scheduler.hot_cache", sched_hot.predict_seconds * 1e6,
             f"hit_rate={hit:.2f}")
        save_json("scheduler", out)
        return out
    finally:
        mde.close()


if __name__ == "__main__":
    run()
