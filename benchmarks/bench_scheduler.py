"""The paper's §1 use case, quantified: predictor-driven heterogeneous
scheduling vs round-robin and single-device baselines, across the five
simulated device models; objective variants time / energy."""
from __future__ import annotations

import numpy as np

from repro.core.devices import SIMULATED_DEVICES
from repro.core.forest import ExtraTreesRegressor
from repro.core.scheduler import DevicePredictor, schedule, speedup_vs_baseline

from .common import StopWatch, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    devs = []
    X_all = None
    for d in SIMULATED_DEVICES:
        X, y, _ = ds.matrix(d.name, "time_us")
        _, p, _ = ds.matrix(d.name, "power_w")
        est_t = ExtraTreesRegressor(n_estimators=32, seed=0).fit(
            X.astype(np.float32), np.log(y))
        est_p = ExtraTreesRegressor(n_estimators=32, seed=1).fit(
            X.astype(np.float32), p)
        devs.append(DevicePredictor(d.name, est_t.predict, est_p.predict,
                                    log_time=True, count=2))
        X_all = X
    with StopWatch() as sw:
        cmp = speedup_vs_baseline(X_all.astype(np.float32), devs)
    sched_e = schedule(X_all.astype(np.float32), devs, objective="energy")
    out = {"makespan": cmp, "energy_objective_j": sched_e.energy_j}
    emit("scheduler.makespan", cmp["predict_seconds"] * 1e6,
         f"speedup_vs_rr={cmp['speedup_vs_rr']:.2f}x;"
         f"speedup_vs_single={cmp['speedup_vs_single']:.2f}x")
    emit("scheduler.energy", sched_e.predict_seconds * 1e6,
         f"energy={sched_e.energy_j:.3f}J")
    save_json("scheduler", out)
    return out


if __name__ == "__main__":
    run()
