"""Paper Table 6: feature importances for time and power per device.
Checks the paper's headline observations: launch-configuration features
(threads/CTA analogue) dominate; the top-3 cover ~50 % of importance."""
from __future__ import annotations

import numpy as np

from repro.core.features import FEATURE_NAMES
from repro.core.forest import ExtraTreesRegressor

from .common import StopWatch, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    out = {}
    for dev in ("tpu-v5e", "tpu-v4", "edge-dvfs", "cpu-host"):
        for target, log_t in (("time_us", True), ("power_w", False)):
            X, y, _ = ds.matrix(dev, target)
            if not len(y):
                continue
            yt = np.log(np.maximum(y, 1e-9)) if log_t else y
            with StopWatch() as sw:
                est = ExtraTreesRegressor(n_estimators=64, seed=0).fit(
                    X.astype(np.float32), yt)
                imp = est.feature_importances_
            order = np.argsort(imp)[::-1]
            table = {FEATURE_NAMES[i]: float(imp[i]) for i in order}
            top3 = float(imp[order[:3]].sum())
            out[f"{dev}.{target}"] = {"importance": table, "top3_cum": top3}
            top = FEATURE_NAMES[order[0]]
            emit(f"importance.table6.{dev}.{target}", sw.seconds * 1e6,
                 f"top={top}:{imp[order[0]]:.2f};top3_cum={top3:.2f}")
    save_json("importance", out)
    return out


if __name__ == "__main__":
    run()
