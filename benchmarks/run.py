"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (see per-module docstrings for
the paper table/figure each one reproduces) and writes JSON artifacts under
artifacts/. Profile via REPRO_BENCH_PROFILE={fast,paper}.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = [
    ("dataset", "paper Fig 2/3/4 + s4.2.3", "benchmarks.bench_dataset"),
    ("cv", "paper Fig 5 (nested CV, primary device)", "benchmarks.bench_cv"),
    ("loo", "paper Fig 6/7 (leave-one-out)", "benchmarks.bench_loo"),
    ("portability", "paper Fig 8/9 + s8 summary", "benchmarks.bench_portability"),
    ("latency", "paper Tables 4/5 (+ beyond-paper paths)", "benchmarks.bench_latency"),
    ("importance", "paper Table 6", "benchmarks.bench_importance"),
    ("baseline", "paper s7.2 AM/LR comparison", "benchmarks.bench_analytical_baseline"),
    ("scheduler", "paper s1 use case quantified", "benchmarks.bench_scheduler"),
    ("forest_kernel", "Pallas forest kernel checks", "benchmarks.bench_forest_kernel"),
    ("roofline", "SRoofline table from dry-run artifacts", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, what, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"bench.{name}.wall,{(time.perf_counter()-t0)*1e6:.0f},"
                  f"ok;{what}")
        except Exception as e:
            traceback.print_exc()
            failures.append(name)
            print(f"bench.{name}.wall,{(time.perf_counter()-t0)*1e6:.0f},"
                  f"FAILED:{type(e).__name__}")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
