"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (see per-module docstrings for
the paper table/figure each one reproduces), writes JSON artifacts under
artifacts/, and consolidates every emitted row into ``BENCH_results.json``
at the repo root (name -> us_per_call/derived) so the perf trajectory is
machine-readable across PRs. Profile via REPRO_BENCH_PROFILE={fast,paper}.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

RESULTS_PATH = Path(__file__).resolve().parents[1] / "BENCH_results.json"

BENCHES = [
    ("dataset", "paper Fig 2/3/4 + s4.2.3", "benchmarks.bench_dataset"),
    ("cv", "paper Fig 5 (nested CV, primary device)", "benchmarks.bench_cv"),
    ("loo", "paper Fig 6/7 (leave-one-out)", "benchmarks.bench_loo"),
    ("portability", "paper Fig 8/9 + s8 summary", "benchmarks.bench_portability"),
    ("latency", "paper Tables 4/5 (+ beyond-paper paths)", "benchmarks.bench_latency"),
    ("importance", "paper Table 6", "benchmarks.bench_importance"),
    ("baseline", "paper s7.2 AM/LR comparison", "benchmarks.bench_analytical_baseline"),
    ("scheduler", "paper s1 use case quantified", "benchmarks.bench_scheduler"),
    ("trace", "workload diversity + trace codec (beyond-paper)", "benchmarks.bench_trace"),
    ("forest_kernel", "Pallas forest kernel checks", "benchmarks.bench_forest_kernel"),
    ("roofline", "SRoofline table from dry-run artifacts", "benchmarks.bench_roofline"),
]


def write_results(ran: list[str], failures: list[str]) -> None:
    """Consolidated machine-readable results at the repo root. Rows are
    keyed by emit() name (duplicates keep the LAST emit); reruns with
    ``--only`` merge into the existing file instead of clobbering other
    benches' rows. ``last_run`` describes THIS invocation only — rows not
    refreshed by it keep their recorded ``profile`` tag, and per-bench
    pass/fail state lives in the ``bench.<name>.wall`` rows themselves."""
    from . import common

    rows: dict = {}
    if RESULTS_PATH.exists():
        try:
            with open(RESULTS_PATH) as f:
                rows = json.load(f).get("rows", {})
        except (OSError, ValueError):
            pass
    for row in common.RESULTS:
        rows[row["name"]] = {"us_per_call": row["us_per_call"],
                             "derived": row["derived"],
                             "profile": common.PROFILE}
    payload = {"rows": rows,
               "last_run": {"profile": common.PROFILE, "ran": ran,
                            "failures": failures}}
    with open(RESULTS_PATH, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"# consolidated {len(common.RESULTS)} rows -> {RESULTS_PATH}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from .common import emit

    print("name,us_per_call,derived")
    failures, ran = [], []
    for name, what, module in BENCHES:
        if only and name not in only:
            continue
        ran.append(name)
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            emit(f"bench.{name}.wall", (time.perf_counter() - t0) * 1e6,
                 f"ok;{what}")
        except Exception as e:
            traceback.print_exc()
            failures.append(name)
            emit(f"bench.{name}.wall", (time.perf_counter() - t0) * 1e6,
                 f"FAILED:{type(e).__name__}")
    write_results(ran, failures)
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
