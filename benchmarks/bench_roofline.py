"""Framework roofline table (§Roofline deliverable): reads the dry-run
artifacts (artifacts/dryrun/*.json) and prints the per-cell three-term
table with dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio and memory fit.
Run ``python -m repro.launch.dryrun --all --mesh both`` first (run.py does
NOT recompute cells; it reports what exists)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit, save_json

DRYRUN = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run() -> dict:
    rows = []
    skipped = 0
    for p in sorted(DRYRUN.glob("*.json")):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            skipped += 1
            continue
        if rec.get("status") != "ok":
            continue
        r = rec["report"]
        rows.append(r)
        emit(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
             max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
             f"dom={r['dominant']};comp={r['t_compute']*1e3:.1f}ms;"
             f"mem={r['t_memory']*1e3:.1f}ms;coll={r['t_collective']*1e3:.1f}ms;"
             f"useful={r['useful_ratio']:.2f};fits_tpu={r['fits_hbm_tpu']}")
    out = {"cells": len(rows), "skipped": skipped,
           "all_fit_tpu": all(r["fits_hbm_tpu"] for r in rows)}
    emit("roofline.summary", 0.0,
         f"cells={len(rows)};skipped={skipped};all_fit_tpu={out['all_fit_tpu']}")
    save_json("roofline_summary", out)
    return out


if __name__ == "__main__":
    run()
