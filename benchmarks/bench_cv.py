"""Paper Fig. 5: nested cross-validation scores for time and power
prediction on the primary device (tpu-v5e plays the K20's role), plus the
real-measurement leg (cpu-host time)."""
from __future__ import annotations


from repro.core.cv import nested_cv

from .common import StopWatch, cv_config, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    out = {}
    jobs = [("tpu-v5e", "time_us", True), ("tpu-v5e", "power_w", False),
            ("cpu-host", "time_us", True)]
    for dev, target, time_split in jobs:
        X, y, _ = ds.matrix(dev, target)
        if not len(y):
            continue
        cfg = cv_config(time_split)
        with StopWatch() as sw:
            res = nested_cv(X, y, cfg)
        s = res.summary()
        s["best_params"] = res.best_params_mode()
        out[f"{dev}.{target}"] = s
        emit(f"cv.fig5.{dev}.{target}", sw.seconds * 1e6,
             f"median_mape={s['median_mape']:.2f}%;"
             f"iqr=({s['q1']:.2f},{s['q3']:.2f});n={len(y)}")
    save_json("cv", out)
    return out


if __name__ == "__main__":
    run()
