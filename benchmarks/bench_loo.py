"""Paper Fig. 6/7: Leave-One-Out predictions — scatter data (true vs
predicted) and the error-bucket distribution (82 % within 10 % for K20 time;
92 % within 5 % for power)."""
from __future__ import annotations


from repro.core.cv import leave_one_out
from repro.core.metrics import ape, error_buckets, mape, median_ape

from .common import PROFILE, StopWatch, dataset, emit, save_json

PARAMS = {"criterion": "mse", "max_features": "max", "n_estimators": 48}


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    out = {}
    max_samples = None if PROFILE == "paper" else 48
    for dev, target, log_t, guard in [("tpu-v5e", "time_us", True, True),
                                      ("tpu-v5e", "power_w", False, False)]:
        X, y, _ = ds.matrix(dev, target)
        with StopWatch() as sw:
            idx, pred = leave_one_out(X, y, PARAMS, log_target=log_t,
                                      time_split_guard=guard,
                                      max_samples=max_samples)
        truth = y[idx]
        errs = ape(truth, pred)
        buckets = error_buckets(truth, pred,
                                edges=(5.0, 10.0, 25.0, 50.0, 100.0))
        lim = 10.0 if target == "time_us" else 5.0   # paper's headline cuts
        within = float((errs <= lim).mean())
        rec = {"mape": mape(truth, pred), "median_ape": median_ape(truth, pred),
               "buckets": buckets, f"within_{lim:g}pct": within, "n": len(idx),
               "scatter": [[float(a), float(b)] for a, b in
                           zip(truth[:50], pred[:50])]}
        out[f"{dev}.{target}"] = rec
        emit(f"loo.fig67.{dev}.{target}", sw.seconds * 1e6 / max(len(idx), 1),
             f"median_ape={rec['median_ape']:.2f}%;within_{lim:g}%={within:.2f};"
             f"n={rec['n']}")
    save_json("loo", out)
    return out


if __name__ == "__main__":
    run()
