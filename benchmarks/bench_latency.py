"""Paper Tables 4/5: best hyperparameters + prediction latency.

The paper measures 15-108 ms per single prediction (256-1024 trees, Xeon).
We report the SAME tree-walk deployment path (paper-faithful baseline) next
to the optimized inference paths (flat-numpy / flat-jax / dense-jax / Pallas
interpret) — the beyond-paper §Perf hillclimb on the paper's own hot spot."""
from __future__ import annotations

import numpy as np

from repro.core.forest import ExtraTreesRegressor
from repro.core.latency import measure_paths

from .common import PROFILE, StopWatch, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    X, y, _ = ds.matrix("tpu-v5e", "time_us")
    n_trees = 512 if PROFILE == "paper" else 128
    est = ExtraTreesRegressor(n_estimators=n_trees, criterion="mse",
                              max_features="max", seed=0)
    est.fit(X.astype(np.float32), np.log(y))
    out = {"n_estimators": n_trees, "avg_depth": est.avg_depth(),
           "paths": {}}
    rows = measure_paths(est, X.astype(np.float32), dense_depth=10)
    base = None
    for r in rows:
        out["paths"][r.name] = {"single_ms": r.single_ms,
                                "batch_us_per_sample": r.batch_us_per_sample}
        if r.name == "tree-walk":
            base = r.single_ms
        speed = f";speedup_vs_paper_path={base / r.single_ms:.0f}x" if base else ""
        emit(f"latency.table45.{r.name}", r.single_ms * 1e3,
             f"batch={r.batch_us_per_sample:.2f}us/sample{speed}")
    save_json("latency", out)
    return out


if __name__ == "__main__":
    run()
