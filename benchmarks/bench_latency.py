"""Paper Tables 4/5: best hyperparameters + prediction latency.

The paper measures 15-108 ms per single prediction (256-1024 trees, Xeon).
We report the SAME tree-walk deployment path (paper-faithful baseline) next
to the optimized inference paths (flat-numpy / flat-jax / dense-jax / Pallas
interpret) — the beyond-paper §Perf hillclimb on the paper's own hot spot —
plus the serving engine's batched path (cold cache, warm cache, and
micro-batched async singles), the numbers a scheduler actually sees — and
the cluster tier's frontend (queue+engine p50/p99 at 1/2/4 replicas), the
frontend SATURATION sweep (p99 vs offered load at ~0.5×/0.9×/1.2× measured
capacity, with shed fraction past the knee), trace-replay rows (p99 + shed fraction
under recorded diurnal/burst/golden-fixture traffic — see
``repro.workloads.trace``), and loopback-TCP remote rows (wire overhead of
the network transport)."""
from __future__ import annotations

import math
import threading
import time

import numpy as np

from repro.core.forest import ExtraTreesRegressor
from repro.core.latency import measure_paths
from repro.serve import EngineConfig, ForestEngine, ShardedForestEngine

from .common import PROFILE, dataset, emit, save_json


def _engine_rows(est, X: np.ndarray) -> dict:
    """Serving-engine throughput: one batched call (cold/warm cache) and a
    burst of async singles riding the micro-batcher."""
    out = {}
    with ForestEngine(est, EngineConfig(backend="auto", max_batch=64,
                                        max_delay_ms=2.0)) as eng:
        out["backend"] = eng.backend
        out["calibration_ms"] = {k: v * 1e3 for k, v in eng.calibration.items()}

        t0 = time.perf_counter()
        eng.predict(X)
        cold = (time.perf_counter() - t0) / X.shape[0] * 1e6
        t0 = time.perf_counter()
        eng.predict(X)                         # same kernels: pure cache hits
        warm = (time.perf_counter() - t0) / X.shape[0] * 1e6
        out["batch_cold_us_per_sample"] = cold
        out["batch_warm_us_per_sample"] = warm
        emit("latency.engine.batch_cold", cold, f"backend={eng.backend}")
        emit("latency.engine.batch_warm", warm,
             f"hit_rate={eng.stats.hit_rate():.2f}")

        eng.cache_clear()
        n = min(256, X.shape[0])
        t0 = time.perf_counter()
        futs = [eng.predict_async(X[i]) for i in range(n)]
        for f in futs:
            f.result(timeout=30)
        burst = (time.perf_counter() - t0) / n * 1e6
        out["async_burst_us_per_sample"] = burst
        out["async_batches"] = eng.stats.batches
        emit("latency.engine.async_burst", burst,
             f"batches={eng.stats.batches};n={n}")

        hit = eng.stats.hit_rate()
        out["cache_hit_rate"] = hit
        emit("latency.engine.hit_rate", hit * 100,
             f"hits={eng.stats.cache_hits};misses={eng.stats.cache_misses};"
             f"unit=percent")
    return out


def _sharded_rows(est, X: np.ndarray, n_shards: int = 2) -> dict:
    """Tree-axis-partitioned engine throughput (loop placement on this
    1-device host; a multi-device runtime switches to the shard_map mesh)."""
    out = {}
    with ShardedForestEngine(est, n_shards=n_shards, max_batch=64) as eng:
        out["backend"] = eng.backend
        out["placement"] = eng.placement
        out["shard_sizes"] = eng.shard_sizes
        t0 = time.perf_counter()
        eng.predict(X)
        cold = (time.perf_counter() - t0) / X.shape[0] * 1e6
        t0 = time.perf_counter()
        eng.predict(X)
        warm = (time.perf_counter() - t0) / X.shape[0] * 1e6
        out["batch_cold_us_per_sample"] = cold
        out["batch_warm_us_per_sample"] = warm
        emit("latency.engine.sharded_cold", cold,
             f"shards={n_shards};placement={eng.placement}")
        emit("latency.engine.sharded_warm", warm,
             f"hit_rate={eng.stats.hit_rate():.2f}")
    return out


def _frontend_rows(est, X: np.ndarray) -> dict:
    """Cluster-tier end-to-end latency: queue wait + engine time through the
    frontend's admission queue, p50/p99, at 1/2/4 replicas. Replicas pin the
    deterministic flat-numpy backend so the rows measure the TIER (queueing,
    routing, dispatch), not backend auto-selection noise."""
    from repro.cluster import ClusterFrontend, ReplicaPool

    out = {}
    n_req = min(256, X.shape[0] * 4)
    for n_replicas in (1, 2, 4):
        engines = {f"r{i}": ForestEngine(est, backend="flat-numpy",
                                         cache_size=0)
                   for i in range(n_replicas)}
        pool = ReplicaPool(engines, check_interval_s=60.0)  # no probe noise
        with ClusterFrontend(pool, max_queue=n_req,
                             dispatch_batch=64) as fe:
            done_s = np.zeros(n_req)
            all_done = threading.Event()
            count_lock = threading.Lock()
            remaining = [n_req]

            def arm(i):
                t0 = time.perf_counter()
                fut = fe.submit(X[i % X.shape[0]])

                def record(_f, i=i, t0=t0):
                    done_s[i] = time.perf_counter() - t0
                    with count_lock:           # callbacks run on several
                        remaining[0] -= 1      # dispatch threads
                        if remaining[0] == 0:
                            all_done.set()
                fut.add_done_callback(record)
                return fut

            t0 = time.perf_counter()
            futs = [arm(i) for i in range(n_req)]
            for f in futs:
                f.result(timeout=60)
            # result() can return before the last done-callback has run on
            # the dispatcher thread; percentiles must see every sample
            all_done.wait(timeout=60)
            wall = time.perf_counter() - t0
            summary = fe.latency_summary()
            row = {
                "replicas": n_replicas,
                "throughput_us_per_sample": wall / n_req * 1e6,
                "e2e_p50_ms": float(np.percentile(done_s, 50)) * 1e3,
                "e2e_p99_ms": float(np.percentile(done_s, 99)) * 1e3,
                **summary,
                "dispatches": fe.stats.dispatches,
                "by_replica": dict(fe.stats.by_replica),
            }
            out[f"x{n_replicas}"] = row
            emit(f"latency.frontend.e2e_p50_x{n_replicas}",
                 row["e2e_p50_ms"] * 1e3,
                 f"wait_p50={summary['wait_p50_ms']:.2f}ms;"
                 f"engine_p50={summary['engine_p50_ms']:.2f}ms")
            emit(f"latency.frontend.e2e_p99_x{n_replicas}",
                 row["e2e_p99_ms"] * 1e3,
                 f"wait_p99={summary['wait_p99_ms']:.2f}ms;"
                 f"engine_p99={summary['engine_p99_ms']:.2f}ms")
            emit(f"latency.frontend.burst_x{n_replicas}",
                 row["throughput_us_per_sample"],
                 f"n={n_req};dispatches={fe.stats.dispatches};"
                 f"replicas={n_replicas}")
    return out


def _saturation_rows(est, X: np.ndarray) -> dict:
    """Frontend SATURATION: p99 end-to-end latency vs OFFERED load.

    Measures the tier's closed-loop capacity (rows/s through a 2-replica
    frontend), then replays an open-loop arrival process at ~0.5×, 0.9×,
    and 1.2× that capacity. Below saturation p99 tracks the engine time;
    near 1× the queue builds; past 1× the admission bound rejects the
    overflow (rejected fraction reported per row) — the knee the
    regression gate watches for. The fast profile (CI's blocking
    bench-regression job) shortens the replay window; row NAMES are
    identical across profiles so the gate diffs them either way."""
    from repro.cluster import ClusterFrontend, FrontendRejected, ReplicaPool

    out = {}
    n_replicas = 2
    window_s = 0.6 if PROFILE == "fast" else 2.0
    cap_rows = 256 if PROFILE == "fast" else 1024
    engines = {f"r{i}": ForestEngine(est, backend="flat-numpy",
                                     cache_size=0)
               for i in range(n_replicas)}
    pool = ReplicaPool(engines, check_interval_s=60.0)
    with ClusterFrontend(pool, max_queue=256, dispatch_batch=32) as fe:
        # capacity: drive admission flat-out (rejections backed off, not
        # counted) and take the SERVED drain rate — the sustainable
        # open-loop throughput the load multipliers are anchored to
        futs = []
        t0 = time.perf_counter()
        while (time.perf_counter() - t0 < window_s
               and len(futs) < cap_rows * 4):
            try:
                futs.append(fe.submit(X[len(futs) % X.shape[0]]))
            except FrontendRejected:
                time.sleep(0.002)
        for f in futs:
            f.result(timeout=60)
        capacity = len(futs) / (time.perf_counter() - t0)  # rows/s
        out["capacity_rows_per_s"] = capacity

        for mult, tag in ((0.5, "0p5"), (0.9, "0p9"), (1.2, "1p2")):
            rate = capacity * mult
            n = max(int(rate * window_s), 32)
            lat_s, rejected, done = [], 0, [0]
            lock = threading.Lock()
            all_done = threading.Event()
            expected = [None]          # set once submission finishes

            def arm(t_arrival):
                def record(f, t0=t_arrival):
                    # Future.result() unblocks BEFORE done-callbacks run:
                    # the percentile wait below keys off this counter, not
                    # off result(), so no completion's latency is missed
                    with lock:
                        if not f.cancelled() and f.exception() is None:
                            lat_s.append(time.perf_counter() - t0)
                        done[0] += 1
                        if expected[0] is not None and done[0] == expected[0]:
                            all_done.set()
                return record

            futs = []
            t_start = time.perf_counter()
            for i in range(n):
                # open-loop pacing: arrivals do NOT wait for completions
                t_due = t_start + i / rate
                delay = t_due - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                try:
                    fut = fe.submit(X[i % X.shape[0]])
                except FrontendRejected:
                    rejected += 1        # overload sheds, as designed
                    continue
                fut.add_done_callback(arm(time.perf_counter()))
                futs.append(fut)
            with lock:
                expected[0] = len(futs)
                if done[0] == expected[0]:
                    all_done.set()
            all_done.wait(timeout=60)
            p99 = float(np.percentile(lat_s, 99)) * 1e3 if lat_s else 0.0
            p50 = float(np.percentile(lat_s, 50)) * 1e3 if lat_s else 0.0
            row = {"offered_mult": mult, "offered_rows_per_s": rate,
                   "requests": n, "served": len(lat_s),
                   "rejected": rejected, "p50_ms": p50, "p99_ms": p99}
            out[f"load{tag}"] = row
            emit(f"latency.frontend.saturation_p99_load{tag}", p99 * 1e3,
                 f"offered={rate:.0f}rows/s;served={len(lat_s)};"
                 f"rejected={rejected};capacity={capacity:.0f}rows/s;"
                 f"replicas={n_replicas}")
    return out


def _trace_rows(est, X: np.ndarray, capacity: float) -> dict:
    """Realistic-traffic rows: the knee, shed fraction, and p99 measured
    under RECORDED traces instead of uniform open-loop Poisson arrivals —
    a diurnal curve below the knee, a Markov-modulated burst trace that
    crosses it, and the COMMITTED golden fixture trace (the same bytes the
    determinism test replays). ``capacity`` anchors the offered rates the
    same way the saturation sweep's multipliers are anchored."""
    from pathlib import Path

    from repro.cluster import ClusterFrontend, ReplicaPool
    from repro.workloads.trace import (TraceReplayer, gen_bursts,
                                       gen_diurnal, load_trace)

    out = {"capacity_rows_per_s": capacity}
    emit("latency.trace.knee", 1e6 / max(capacity, 1e-9),
         f"capacity={capacity:.0f}rows/s;us_per_row_at_knee")
    window_s = 1.0 if PROFILE == "fast" else 4.0
    max_events = 600 if PROFILE == "fast" else 2400
    ids = [f"k{i}" for i in range(X.shape[0])]

    # event COUNTS are bounded by the budget; the OFFERED rate is anchored
    # to measured capacity through the replay speed, so the same rows mean
    # the same thing on a fast host and a loaded CI runner
    rate_lo = max_events / window_s
    diurnal = gen_diurnal(ids, X, duration_s=window_s, mean_rate=rate_lo,
                          peak_to_trough=3.0, seed=21)
    rate_burst = 4 * max_events / window_s
    bursts = gen_bursts(ids, X, duration_s=window_s,
                        rate_quiet=rate_lo / 2, rate_burst=rate_burst,
                        mean_quiet_s=window_s / 4,
                        mean_burst_s=window_s / 10, seed=22)
    fixture = load_trace(Path(__file__).resolve().parents[1] / "tests"
                         / "fixtures" / "trace_golden_v1.jsonl")
    # diurnal cruises below the knee (peak ~0.9x capacity); the bursts
    # PEAK at ~2.5x capacity so the admission bound actually sheds; the
    # fixture replays at ~0.8x capacity (realistic but sustainable)
    speed_diurnal = max(0.6 * capacity / max(diurnal.mean_rate(), 1e-9), 1.0)
    speed_burst = max(2.5 * capacity / rate_burst, 1.0)
    fixture_speed = max(0.8 * capacity / max(fixture.mean_rate(), 1e-9),
                        1.0)

    # diurnal/fixture clients retry once on backpressure (the polite
    # client); the burst row is NO-retry, so its shed fraction is exactly
    # the admission-bound overflow at the knee — a retrying client hides
    # it by resubmitting after the burst has passed
    for tag, trace, speed, retries in (
            ("diurnal", diurnal, speed_diurnal, 1),
            ("burst", bursts, speed_burst, 0),
            ("fixture", fixture, fixture_speed, 1)):
        engines = {f"r{i}": ForestEngine(est, backend="flat-numpy",
                                         cache_size=0) for i in range(2)}
        pool = ReplicaPool(engines, check_interval_s=60.0)
        with ClusterFrontend(pool, max_queue=64, dispatch_batch=32) as fe:
            rep = TraceReplayer(fe, pacing="open", speed=speed,
                                max_retries=retries).replay(trace)
        row = {"events": rep.n_events, "served": rep.count("served"),
               "shed": rep.count("shed"), "expired": rep.count("expired"),
               "shed_fraction": rep.shed_fraction(),
               "retries": sum(s.retries for s in rep.per_tenant.values()),
               "offered_rows_per_s": trace.mean_rate() * speed,
               "p50_ms": rep.served_wall_ms(50),
               "p99_ms": rep.served_wall_ms(99),
               "per_tenant_shed": {t: s.shed_fraction()
                                   for t, s in rep.per_tenant.items()}}
        out[tag] = row
        emit(f"latency.trace.p99_{tag}", row["p99_ms"] * 1e3,
             f"offered={row['offered_rows_per_s']:.0f}rows/s;"
             f"served={row['served']};shed={row['shed']};"
             f"capacity={capacity:.0f}rows/s")
        emit(f"latency.trace.shed_{tag}", row["shed_fraction"] * 100,
             f"events={row['events']};max_retries={retries};"
             f"retries={row['retries']};unit=percent")
    return out


def _remote_rows(est, X: np.ndarray) -> dict:
    """Transport overhead, tracked from day one: single-prediction p50/p99
    through a loopback-TCP ``PredictionServer`` vs the SAME frontend called
    in-process — the delta is what the wire costs, with queueing/dispatch
    identical on both sides.

    The v2 JSON rows (``latency.remote.p50/p99/batch``) are kept as the
    comparison baseline via a protocol-pinned replica; the PR-7 rows
    measure the binary zero-copy path: ``batch_v3`` is WIRE overhead per
    row (min-of-k remote batch minus min-of-k in-process submit_batch
    through the same frontend — min-of-k on both sides cancels the ~90
    us/row forest compute and its noise), ``pipelined_p99`` is per-request
    p99 with 8 threads sharing ONE socket, and ``interop`` interleaves v2
    and v3 peers against one server (the rolling-upgrade mix)."""
    from concurrent.futures import ThreadPoolExecutor

    from repro.cluster import (PROTOCOL_VERSION, ClusterFrontend,
                               PredictionServer, RemoteReplica, ReplicaPool)

    out = {}
    n, k = 96, 5
    rows_n = X.shape[0]
    engine = ForestEngine(est, backend="flat-numpy", cache_size=0)
    pool = ReplicaPool({"r0": engine}, check_interval_s=60.0)
    # queue must fit the full batched call: a v2 predict frame submits one
    # entry per row, a v3 frame one batch entry of the same row count
    fe = ClusterFrontend(pool, max_queue=max(n, rows_n) + 8,
                         dispatch_batch=64, auto_start=False)
    with PredictionServer(fe, port=0) as server:
        replica = RemoteReplica(server.address, timeout_s=30.0,
                                protocol=PROTOCOL_VERSION)   # v2 baseline
        replica.predict(X[:4])                 # connect + hello + warm path
        fe.predict(X[:4])

        remote_s = np.empty(n)
        for i in range(n):
            t0 = time.perf_counter()
            replica.predict(X[i % rows_n][None, :], deadline_s=10.0)
            remote_s[i] = time.perf_counter() - t0
        inproc_s = np.empty(n)
        for i in range(n):
            t0 = time.perf_counter()
            fe.submit(X[i % rows_n], deadline_s=10.0).result(timeout=30)
            inproc_s[i] = time.perf_counter() - t0

        t0 = time.perf_counter()
        replica.predict(X, deadline_s=30.0)    # one batched wire call
        batch_us = (time.perf_counter() - t0) / rows_n * 1e6

        for label, arr in (("remote", remote_s), ("inproc", inproc_s)):
            for p in (50, 99):
                out[f"{label}_p{p}_ms"] = float(
                    np.percentile(arr, p)) * 1e3
        out["batch_us_per_sample"] = batch_us
        out["overhead_p50_ms"] = out["remote_p50_ms"] - out["inproc_p50_ms"]
        emit("latency.remote.p50", out["remote_p50_ms"] * 1e3,
             f"inproc_p50={out['inproc_p50_ms']:.2f}ms;"
             f"wire_overhead={out['overhead_p50_ms']:.2f}ms;n={n}")
        emit("latency.remote.p99", out["remote_p99_ms"] * 1e3,
             f"inproc_p99={out['inproc_p99_ms']:.2f}ms;n={n}")
        emit("latency.remote.batch", batch_us,
             f"rows={rows_n};loopback_tcp=1;protocol=2")

        # ---- v3 binary zero-copy: wire overhead per row ----------------
        v3 = RemoteReplica(server.address, timeout_s=30.0)
        v3.predict(X[:4], deadline_s=10.0)     # negotiate + warm
        t_remote = min(_timed(lambda: v3.predict(X, deadline_s=30.0))
                       for _ in range(k))
        t_inproc = min(
            _timed(lambda: fe.submit_batch(
                X, deadline_s=30.0).result(timeout=30))
            for _ in range(k))
        v3_wire_us = max((t_remote - t_inproc) / rows_n * 1e6, 0.0)
        out["batch_v3_wire_us_per_row"] = v3_wire_us
        out["batch_v3_total_us_per_row"] = t_remote / rows_n * 1e6
        out["batch_v2_over_v3_wire"] = (
            (batch_us - t_inproc / rows_n * 1e6) / max(v3_wire_us, 1e-9))
        emit("latency.remote.batch_v3", v3_wire_us,
             f"rows={rows_n};negotiated=v{v3.negotiated_version};"
             f"total={t_remote / rows_n * 1e6:.1f}us/row;"
             f"inproc={t_inproc / rows_n * 1e6:.1f}us/row;min_of={k}")

        # ---- pipelined singles: 8 threads, ONE socket ------------------
        threads, per = 8, 12
        lat = np.empty(threads * per)

        def _burst(w):
            for j in range(per):
                i = (w * per + j) % rows_n
                t0 = time.perf_counter()
                v3.predict(X[i][None, :], deadline_s=10.0)
                lat[w * per + j] = time.perf_counter() - t0

        with ThreadPoolExecutor(max_workers=threads) as ex:
            list(ex.map(_burst, range(threads)))
        out["pipelined_p50_ms"] = float(np.percentile(lat, 50)) * 1e3
        out["pipelined_p99_ms"] = float(np.percentile(lat, 99)) * 1e3
        out["pipelined_max_in_flight"] = v3.stats.max_in_flight
        emit("latency.remote.pipelined_p99", out["pipelined_p99_ms"] * 1e3,
             f"threads={threads};calls={threads * per};"
             f"max_in_flight={v3.stats.max_in_flight};"
             f"serial_v2_p99={out['remote_p99_ms']:.2f}ms")

        # ---- mixed v2/v3 interop: both dialects against one server -----
        t0 = time.perf_counter()
        rounds = 3
        for _ in range(rounds):
            v3.predict(X, deadline_s=30.0)
            replica.predict(X, deadline_s=30.0)
        interop_us = ((time.perf_counter() - t0)
                      / (2 * rounds * rows_n) * 1e6)
        out["interop_us_per_row"] = interop_us
        emit("latency.remote.interop", interop_us,
             f"rows={rows_n};rounds={rounds};dialects=v2+v3")
        v3.close()
        replica.close()
    return out


def _obs_rows(est, X: np.ndarray) -> dict:
    """Observability overhead on the hot path: the SAME v3 batched call as
    ``latency.remote.batch_v3``, measured against twin loopback servers —
    one bare, one fully instrumented (metrics registry wired through
    frontend/pool/engine/server, request tracing on BOTH ends, a trace
    context on every call so the full admit→…→reply span tree is built and
    shipped back). Reported as instrumented total us/row with the percent
    delta over the bare twin in the detail string; the acceptance bar is
    that the delta stays within run-to-run noise (<=5%)."""
    from repro.cluster import (ClusterFrontend, PredictionServer,
                               RemoteReplica, ReplicaPool)
    from repro.obs import Observability

    k, rows_n = 7, X.shape[0]

    def _stack(instrumented: bool):
        obs = Observability.default() if instrumented else None
        client_obs = Observability.default() if instrumented else None
        engine = ForestEngine(est, backend="flat-numpy", cache_size=0)
        if obs is not None:
            engine.register_metrics(obs.registry, replica="r0")
        pool = ReplicaPool({"r0": engine}, check_interval_s=60.0)
        fe = ClusterFrontend(pool, max_queue=rows_n + 8, dispatch_batch=64,
                             auto_start=False, obs=obs)
        server = PredictionServer(fe, port=0, obs=obs).start()
        rep = RemoteReplica(server.address, timeout_s=30.0, obs=client_obs)

        def call():
            if client_obs is None:
                rep.predict(X, deadline_s=30.0)
                return
            root = client_obs.tracer.start("bench.request")
            rep.predict(X, deadline_s=30.0, trace_ctx=root.ctx)
            client_obs.tracer.finish(root)

        return call, rep, server

    # both stacks up-front, calls INTERLEAVED bare/instrumented so machine
    # drift hits both equally and min-of-k compares like with like
    bare_call, bare_rep, bare_srv = _stack(False)
    obs_call, obs_rep, obs_srv = _stack(True)
    try:
        bare_call()                    # connect + negotiate + warm
        obs_call()
        t_bare, t_obs = math.inf, math.inf
        for _ in range(k):
            t_bare = min(t_bare, _timed(bare_call))
            t_obs = min(t_obs, _timed(obs_call))
    finally:
        bare_rep.close()
        obs_rep.close()
        bare_srv.close()
        obs_srv.close()
    bare_us = t_bare / rows_n * 1e6
    obs_us = t_obs / rows_n * 1e6
    pct = (t_obs - t_bare) / t_bare * 100.0
    out = {"bare_us_per_row": bare_us, "instrumented_us_per_row": obs_us,
           "overhead_pct": pct, "min_of": k}
    emit("latency.obs.overhead", obs_us,
         f"rows={rows_n};bare={bare_us:.1f}us/row;"
         f"overhead_pct={pct:+.1f};min_of={k};traced=1")
    return out


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    X, y, _ = ds.matrix("tpu-v5e", "time_us")
    n_trees = 512 if PROFILE == "paper" else 128
    est = ExtraTreesRegressor(n_estimators=n_trees, criterion="mse",
                              max_features="max", seed=0)
    est.fit(X.astype(np.float32), np.log(y))
    out = {"n_estimators": n_trees, "avg_depth": est.avg_depth(),
           "paths": {}}
    rows = measure_paths(est, X.astype(np.float32), dense_depth=10)
    base = None
    for r in rows:
        out["paths"][r.name] = {"single_ms": r.single_ms,
                                "batch_us_per_sample": r.batch_us_per_sample}
        if r.name == "tree-walk":
            base = r.single_ms
        speed = f";speedup_vs_paper_path={base / r.single_ms:.0f}x" if base else ""
        emit(f"latency.table45.{r.name}", r.single_ms * 1e3,
             f"batch={r.batch_us_per_sample:.2f}us/sample{speed}")
    out["engine"] = _engine_rows(est, X.astype(np.float32))
    out["sharded"] = _sharded_rows(est, X.astype(np.float32))
    out["frontend"] = _frontend_rows(est, X.astype(np.float32))
    out["saturation"] = _saturation_rows(est, X.astype(np.float32))
    out["trace"] = _trace_rows(est, X.astype(np.float32),
                               out["saturation"]["capacity_rows_per_s"])
    out["remote"] = _remote_rows(est, X.astype(np.float32))
    out["obs"] = _obs_rows(est, X.astype(np.float32))
    save_json("latency", out)
    return out


if __name__ == "__main__":
    run()
