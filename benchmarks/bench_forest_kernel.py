"""Forest-kernel scaling: the Pallas MXU formulation vs the gather-based
reference across batch sizes and tree counts (interpret-mode wall times are
NOT TPU times — the deliverable here is correctness at scale plus the
structural VMEM/FLOP accounting printed for the §Perf discussion)."""
from __future__ import annotations


import numpy as np

from repro.core.forest import ExtraTreesRegressor
from repro.core.forest_jax import DenseForestJax, to_dense
from repro.kernels.forest import forest_predict

from .common import StopWatch, dataset, emit, save_json


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    X, y, _ = ds.matrix("tpu-v5e", "time_us")
    Xf = X.astype(np.float32)
    est = ExtraTreesRegressor(n_estimators=64, seed=0).fit(Xf, np.log(y))
    out = {}
    for depth in (8, 10):
        dense = to_dense(est, depth=depth)
        ref = DenseForestJax(dense)
        for B in (8, 64):
            xq = np.repeat(Xf, max(1, B // len(Xf) + 1), 0)[:B]
            r = np.asarray(ref(xq))
            with StopWatch() as sw:
                o = np.asarray(forest_predict(xq, dense.feature,
                                              dense.threshold, dense.value,
                                              depth=depth))
            err = float(np.abs(o - r).max())
            # structural accounting: one-hot contraction FLOPs + VMEM bytes
            T, N = dense.feature.shape
            flops = 2.0 * B * T * sum(2 ** d * 16 for d in range(depth))
            vmem = (8 * 16 + 3 * 32 * N) * 4 + 8 * 32 * (2 ** depth) * 4
            out[f"d{depth}_b{B}"] = {"max_err": err, "mxu_flops": flops,
                                     "vmem_bytes": vmem}
            emit(f"forest_kernel.d{depth}.b{B}", sw.seconds * 1e6,
                 f"max_err={err:.2e};mxu_flops={flops:.2e};"
                 f"vmem={vmem/2**20:.2f}MiB")
    save_json("forest_kernel", out)
    return out


if __name__ == "__main__":
    run()
