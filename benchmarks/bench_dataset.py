"""Paper Fig. 2 (execution-time histogram over log scale), Fig. 3/4
(coefficient of variation vs duration / power), §4.2.3 (over-representation
reduction)."""
from __future__ import annotations

import numpy as np

from .common import StopWatch, dataset, emit, save_json


def run() -> dict:
    with StopWatch() as sw:
        ds = dataset()
    out = {"n_samples": len(ds), "devices": ds.devices()}

    for dev in ("cpu-host", "tpu-v5e"):
        X, y, kept = ds.matrix(dev, "time_us")
        if not len(y):
            continue
        stats = ds.stats(dev)
        out[dev] = stats
        # Fig 3: CoV shrinks with duration
        covs = np.asarray([s.targets[dev].get("time_cov", 0) for s in kept])
        short = covs[y < np.median(y)].mean()
        long_ = covs[y >= np.median(y)].mean()
        out[dev]["cov_short"] = float(short)
        out[dev]["cov_long"] = float(long_)
        emit(f"dataset.fig2.{dev}", sw.seconds * 1e6 / max(len(ds), 1),
             f"n={stats['n']};range=10^{stats['orders_of_magnitude']:.1f};"
             f"cov_short={short:.3f};cov_long={long_:.3f}")

    # Fig 4 analogue: power CoV < 5 %
    _, p, kept = ds.matrix("tpu-v5e", "power_w")
    pcov = np.asarray([s.targets["tpu-v5e"].get("power_cov", 0) for s in kept])
    out["power_cov_mean"] = float(pcov.mean())
    emit("dataset.fig4.power_cov", 0.0, f"mean_cov={pcov.mean():.4f}")

    red = ds.reduce_overrepresented(max_per_group=100)
    out["after_reduction"] = len(red)
    emit("dataset.reduction", 0.0, f"{len(ds)}->{len(red)}")
    save_json("dataset", out)
    return out


if __name__ == "__main__":
    run()
