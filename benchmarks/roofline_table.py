"""Render the §Roofline markdown table from artifacts/dryrun/*.json and
splice it into EXPERIMENTS.md at the <!-- ROOFLINE_TABLE --> marker.

    PYTHONPATH=src python -m benchmarks.roofline_table
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "artifacts" / "dryrun"
MARK = "<!-- ROOFLINE_TABLE -->"


def note_for(r: dict) -> str:
    dom = r["dominant"]
    shape = r["shape"]
    if shape.startswith(("decode", "long")):
        return ("cache reads are the floor of 1-token decoding; bigger "
                "decode batch or quantized (int8) cache moves it")
    if dom == "collective":
        if "moe" in r["arch"] or "granite" in r["arch"] or "olmoe" in r["arch"]:
            return ("shard_map all-to-all expert dispatch would replace the "
                    "scatter-add all-reduce (~2.5x less volume)")
        return ("overlap weight-gathers/grad-reductions with compute "
                "(async collectives); fewer microbatches trades memory "
                "for gather volume")
    if dom == "memory":
        return ("raise per-device arithmetic intensity: larger per-device "
                "batch or fewer chips for this model size")
    return "compute-bound: already at the useful-flops ceiling for this mix"


def rows():
    out = []
    for p in sorted(DRYRUN.glob("*.json")):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        out.append(rec["report"])
    return out


def render(include_decode: bool = True) -> str:
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant |"
        " useful | roofline | mem(TPU) | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows():
        if not include_decode and r["shape"].startswith(("decode", "long")):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh'].replace('pod','')} | "
            f"{r['t_compute']*1e3:,.0f} ms | {r['t_memory']*1e3:,.0f} ms | "
            f"{r['t_collective']*1e3:,.0f} ms | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} | "
            f"{r['peak_bytes_tpu']/2**30:.1f} GiB | {note_for(r)} |")
    n_ok = len(rows())
    n_skip = len([p for p in DRYRUN.glob('*.json')
                  if json.load(open(p)).get('status') == 'skipped'])
    lines.append("")
    lines.append(f"({n_ok} compiled cells; {n_skip} documented long_500k "
                 f"skips — full-attention archs, DESIGN.md §4.)")
    return "\n".join(lines)


def main():
    table = render()
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    assert MARK in text, "marker missing"
    pre, _, post = text.partition(MARK)
    # remove any previously spliced table (up to the next section break)
    post_lines = post.split("\n")
    keep = 0
    for i, l in enumerate(post_lines):
        if l.startswith("Per-cell one-line"):
            keep = i
            break
    post = "\n".join(post_lines[keep:])
    exp.write_text(pre + MARK + "\n\n" + table + "\n\n" + post)
    print(f"spliced {len(rows())} rows into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
