"""Workload diversity + trace codec: the scenario-coverage benchmark.

The paper's model is built from 189 kernels across Parboil, Rodinia,
Polybench-GPU and SHOC (§4.1); what makes that number matter is the
FEATURE-SPACE diversity behind it, not the count. This bench scores the
grown suite against the PR-1..5 seed suite with
``workloads.suite.feature_coverage`` (per-feature quantile occupancy +
pairwise joint coverage, common grid), reports a per-family breakdown (the
workload-catalog table in docs/cluster.md), and measures the recorded-trace
codec (``workloads/trace.py``) — encode/decode throughput per event and
generator cost — so trace tooling regressions show up in the same gate as
every other hot path.

Rows: ``workloads.suite.kernels`` (count), ``workloads.coverage.*``
(percent; informational — the gate skips unit=percent rows),
``latency.trace.codec_*`` and ``latency.trace.gen_*`` (us/event, gated via
the ``latency.trace.`` threshold family in diff_results.py).
"""
from __future__ import annotations

import time

import numpy as np

from repro.workloads.suite import (FAMILIES, feature_coverage, kernel_names,
                                   seed_kernel_names)
from repro.workloads.trace import (dumps_trace, gen_adversarial, gen_bursts,
                                   gen_diurnal, gen_tenant_mix, loads_trace)

from .common import dataset, emit, save_json


def _coverage_rows(ds) -> dict:
    """Seed-vs-grown coverage on the COLLECTED dataset's features, scored
    on the full suite's grid so the subset cannot win on range."""
    X, _, kept = ds.matrix("tpu-v5e", "time_us")
    labels = [(s.app, s.kernel) for s in kept]
    suite_mask = np.array([lab in set(kernel_names()) for lab in labels])
    Xs = X[suite_mask]
    s_labels = [lab for lab, m in zip(labels, suite_mask) if m]
    seed = seed_kernel_names()
    seed_mask = np.array([lab in seed for lab in s_labels])

    full = feature_coverage(Xs)
    seed_cov = feature_coverage(Xs[seed_mask], ref=Xs)
    out = {"full": full, "seed": seed_cov, "families": {}}
    n_kernels = len(set(s_labels))
    emit("workloads.suite.kernels", n_kernels,
         f"seed={len(seed)};samples={Xs.shape[0]};unit=count")
    emit("workloads.coverage.seed", seed_cov["score"] * 100,
         f"occupancy={seed_cov['feature_occupancy']:.3f};"
         f"pairwise={seed_cov['pairwise']:.3f};unit=percent")
    emit("workloads.coverage.full", full["score"] * 100,
         f"occupancy={full['feature_occupancy']:.3f};"
         f"pairwise={full['pairwise']:.3f};"
         f"gain={(full['score'] - seed_cov['score']) * 100:.1f}pp;"
         f"unit=percent")
    for fam in FAMILIES + ("misc",):
        fam_mask = np.array([lab[0] == fam for lab in s_labels])
        if not fam_mask.any():
            continue
        cov = feature_coverage(Xs[fam_mask], ref=Xs)
        out["families"][fam] = {
            "kernels": len({lab for lab in s_labels if lab[0] == fam}),
            **{k: cov[k] for k in ("feature_occupancy", "pairwise",
                                   "score", "n_samples")}}
        emit(f"workloads.coverage.family_{fam}", cov["score"] * 100,
             f"kernels={out['families'][fam]['kernels']};unit=percent")
    return out


def _codec_rows(ds) -> dict:
    """Trace generation + codec throughput over the real feature catalog."""
    X, _, kept = ds.matrix("tpu-v5e", "time_us")
    ids = [f"{s.app}/{s.kernel}/{s.variant}" for s in kept]

    t0 = time.perf_counter()
    traces = {
        "diurnal": gen_diurnal(ids, X, duration_s=30.0, mean_rate=40.0,
                               seed=1),
        "bursts": gen_bursts(ids, X, duration_s=30.0, rate_quiet=10.0,
                             rate_burst=160.0, mean_quiet_s=4.0,
                             mean_burst_s=1.0, seed=2),
        "adversarial": gen_adversarial(ids, X, duration_s=30.0, rate=40.0,
                                       seed=3),
        "tenant_mix": gen_tenant_mix(
            ids, X, duration_s=30.0, seed=4,
            tenants={"interactive": {"rate": 25.0,
                                     "deadline_band": (0.2, 1.0)},
                     "batch": {"rate": 15.0, "deadline_band": None}}),
    }
    n_events = sum(len(t) for t in traces.values())
    gen_us = (time.perf_counter() - t0) / n_events * 1e6
    emit("latency.trace.gen_us_per_event", gen_us,
         f"events={n_events};shapes={len(traces)}")

    blobs = {k: dumps_trace(t) for k, t in traces.items()}
    t0 = time.perf_counter()
    for _ in range(3):
        for t in traces.values():
            dumps_trace(t)
    enc_us = (time.perf_counter() - t0) / (3 * n_events) * 1e6
    t0 = time.perf_counter()
    for _ in range(3):
        for b in blobs.values():
            loads_trace(b)
    dec_us = (time.perf_counter() - t0) / (3 * n_events) * 1e6
    emit("latency.trace.codec_encode", enc_us, f"events={n_events}")
    emit("latency.trace.codec_decode", dec_us,
         f"events={n_events};crc_checked=1")
    return {"events": n_events, "gen_us_per_event": gen_us,
            "encode_us_per_event": enc_us, "decode_us_per_event": dec_us,
            "trace_bytes": {k: len(b) for k, b in blobs.items()},
            "per_shape_events": {k: len(t) for k, t in traces.items()}}


def run() -> dict:
    ds = dataset().reduce_overrepresented()
    out = {"coverage": _coverage_rows(ds), "codec": _codec_rows(ds)}
    save_json("trace", out)
    return out


if __name__ == "__main__":
    run()
